"""Checkpoint layer: descriptor-WAL atomic commit, crash-at-every-persist
recovery, elastic restore, async overlap — the paper's technique at file
granularity (DESIGN.md Sec. 2.3)."""
import numpy as np
import pytest

from repro import (AsyncCheckpointManager, CheckpointManager, Committer,
                   MarkerCommitter, PMemPool, SimulatedCrash, data_rel)


def _targets(c, names, ver):
    return [(n, c.slot_version(n), ver) for n in names]


def test_commit_all_or_nothing(tmp_path):
    pool = PMemPool(tmp_path)
    c = Committer(pool)
    names = ["params.h0", "opt.h0", "data_state"]
    ok = c.commit("c1", _targets(c, names, 1),
                  {n: f"v1-{n}".encode() for n in names})
    assert ok
    assert all(c.slot_version(n) == 1 for n in names)
    # wrong expected version -> entire commit fails, nothing moves
    bad = [("params.h0", 1, 2), ("opt.h0", 99, 2), ("data_state", 1, 2)]
    ok = c.commit("c2", bad, {n: b"v2" for n, _, _ in bad})
    assert not ok
    assert all(c.slot_version(n) == 1 for n in names)


def test_commit_payloads_roundtrip(tmp_path):
    pool = PMemPool(tmp_path)
    c = Committer(pool)
    c.commit("c1", [("a", 0, 7)], {"a": b"hello"})
    assert pool.read(data_rel("a", c.slot_version("a"))) == b"hello"


@pytest.mark.parametrize("committer_cls", [Committer, MarkerCommitter])
def test_failed_commit_gcs_desired_data(tmp_path, committer_cls):
    """Regression: a failed commit must delete the desired data files it
    wrote in step 1 instead of leaking orphaned data/*.bin until the next
    recover()."""
    pool = PMemPool(tmp_path)
    c = committer_cls(pool)
    names = ["a", "b"]
    assert c.commit("c1", [(n, 0, 1) for n in names],
                    {n: b"v1" for n in names})
    assert sorted(pool.listdir("data")) == ["a.v1.bin", "b.v1.bin"]
    # 'a' reserves fine (exp matches), 'b' fails its expected check ->
    # the whole commit rolls back; both desired files must be GC'd
    bad = [("a", 1, 2), ("b", 99, 2)]
    assert not c.commit("c2", bad, {n: b"v2" for n, _, _ in bad})
    assert c.slot_version("a") == 1 and c.slot_version("b") == 1
    assert sorted(pool.listdir("data")) == ["a.v1.bin", "b.v1.bin"]


def test_failed_commit_gc_spares_live_versions(tmp_path):
    """The failure-path GC must not delete a desired file that equals the
    slot's live version (degenerate no-op commit shapes)."""
    pool = PMemPool(tmp_path)
    c = Committer(pool)
    assert c.commit("c1", [("a", 0, 1)], {"a": b"v1"})
    # desired == live version, expected wrong -> fails, but a.v1.bin stays
    assert not c.commit("c2", [("a", 99, 1)], {"a": b"v1"})
    assert c.slot_version("a") == 1
    assert pool.listdir("data") == ["a.v1.bin"]
    assert pool.read(data_rel("a", 1)) == b"v1"


@pytest.mark.parametrize("committer_cls", [Committer, MarkerCommitter])
def test_noop_version_commit_rejected_keeps_data(tmp_path, committer_cls):
    """Regression: an exp == des 'no-op move' used to pass every check and
    then GC its own live data file in step 6 (data loss with the slot
    still pointing at the deleted version).  Versions must advance."""
    pool = PMemPool(tmp_path)
    c = committer_cls(pool)
    assert c.commit("c1", [("a", 0, 1)], {"a": b"GOOD"})
    assert not c.commit("c2", [("a", 1, 1)], {"a": b"GOOD"})
    assert c.slot_version("a") == 1
    assert pool.read(data_rel("a", 1)) == b"GOOD"   # live data intact


@pytest.mark.parametrize("committer_cls", [Committer, MarkerCommitter])
def test_failed_commit_never_clobbers_live_data(tmp_path, committer_cls):
    """Regression: a commit whose desired version collides with the slot's
    LIVE version must refuse before step 1 writes anything — otherwise the
    failed commit's payload would silently replace the live data file."""
    pool = PMemPool(tmp_path)
    c = committer_cls(pool)
    assert c.commit("c1", [("a", 0, 1)], {"a": b"GOOD"})
    assert not c.commit("c2", [("a", 99, 1)], {"a": b"EVIL"})
    assert c.slot_version("a") == 1
    assert pool.read(data_rel("a", 1)) == b"GOOD"


@pytest.mark.parametrize("committer_cls", [Committer, MarkerCommitter])
def test_crash_at_every_persist_recovers(tmp_path, committer_cls):
    """Sweep the crash point across the whole commit protocol: after
    recovery, all slots are either all-old or all-new."""
    names = [f"s{i}" for i in range(4)]
    # First, a clean base commit so every slot starts at version 1.
    base = PMemPool(tmp_path / "base")
    committer_cls(base).commit(
        "c0", [(n, 0, 1) for n in names], {n: b"old" for n in names})
    total_persists = None
    for crash_at in range(0, 40):
        root = tmp_path / f"run{committer_cls.__name__}{crash_at}"
        pool = PMemPool(root)
        c = committer_cls(pool)
        c.commit("c0", [(n, 0, 1) for n in names],
                 {n: b"old" for n in names})
        pool.persist_count = 0
        pool.crash_after = crash_at
        try:
            c.commit("c1", [(n, 1, 2) for n in names],
                     {n: b"new" for n in names})
            total_persists = pool.persist_count
            crashed = False
        except SimulatedCrash:
            crashed = True
        pool2 = pool.crash()
        c2 = committer_cls(pool2)
        versions = c2.recover()
        vs = {versions[n] for n in names}
        assert len(vs) == 1, f"torn checkpoint at crash_at={crash_at}: " \
                             f"{versions}"
        ver = vs.pop()
        assert ver in (1, 2)
        # the data for the recovered version must be readable
        for n in names:
            data = pool2.read(data_rel(n, ver))
            assert data == (b"old" if ver == 1 else b"new")
        if not crashed:
            break
    assert total_persists is not None, "sweep never reached completion"


@pytest.mark.parametrize("committer_cls", [Committer, MarkerCommitter])
def test_prune_completed_removes_spent_wal_records(tmp_path, committer_cls):
    """WAL hygiene: every commit leaves a descriptor under wal/;
    prune_completed durably removes the spent ones, and recovery over
    the pruned pool is unaffected (the regression this guards)."""
    pool = PMemPool(tmp_path)
    c = committer_cls(pool)
    for i, name in enumerate(["a", "b", "cc"]):
        assert c.commit(f"c{i}", [(name, 0, 1)], {name: b"v1"})
    assert len(pool.listdir("wal")) == 3
    assert c.prune_completed() == 3
    assert pool.listdir("wal") == []
    # a reopened pool (crash analogue: only durable state) recovers the
    # identical versions — prune's deletes are durable, slots suffice
    c2 = committer_cls(PMemPool(tmp_path))
    assert c2.recover() == {"a": 1, "b": 1, "cc": 1}


def test_prune_completed_keeps_inflight_descriptors(tmp_path):
    """A descriptor still referenced by a slot (mid-commit crash shape)
    must survive pruning — recovery needs it to roll the slot forward."""
    pool = PMemPool(tmp_path)
    c = Committer(pool)
    assert c.commit("c1", [("a", 0, 1)], {"a": b"v1"})
    # hand-build an in-flight commit: descriptor persisted, slot reserved
    pool.write_record("wal/c2.json", {"id": "c2", "state": "SUCCEEDED",
                                      "targets": [["a", 1, 2]], "ts": 0.0})
    pool.write_record("slots/a.json", {"desc": "c2", "expected": 1})
    assert c.prune_completed() == 1            # only the spent c1 record
    assert pool.listdir("wal") == ["c2.json"]
    assert c.slot_version("a") == 2            # resolution still works
    c.recover()                                # finalizes the slot
    assert c.prune_completed() == 1            # now c2 is spent too
    assert pool.listdir("wal") == []
    assert c.slot_version("a") == 2


def test_wal_committer_fewer_persists_than_markers(tmp_path):
    """The paper's claim transferred: dropping per-slot markers saves
    2 persists per slot."""
    names = [f"s{i}" for i in range(8)]
    p1 = PMemPool(tmp_path / "wal")
    c1 = Committer(p1)
    c1.commit("c", [(n, 0, 1) for n in names], {n: b"x" for n in names})
    p2 = PMemPool(tmp_path / "mk")
    c2 = MarkerCommitter(p2)
    c2.commit("c", [(n, 0, 1) for n in names], {n: b"x" for n in names})
    assert p2.persist_count - p1.persist_count == 2 * len(names)


def test_manager_save_restore_roundtrip(tmp_path):
    m = CheckpointManager(tmp_path, n_hosts=2)
    state = {
        "params": {"w": np.arange(16, dtype=np.float32).reshape(4, 4),
                   "b": np.ones(4, np.float32)},
        "opt": {"m": np.zeros((4, 4), np.float32)},
        "data_state": {"position": np.asarray(1234)},
    }
    assert m.save(1, state)
    step, got = m.restore()
    assert step == 1
    np.testing.assert_array_equal(got["params"]["w"], state["params"]["w"])
    np.testing.assert_array_equal(got["data_state"]["position"], 1234)


def test_manager_elastic_reshard(tmp_path):
    """Save from 4 hosts, restore onto 2 — leaves re-concatenate exactly."""
    m4 = CheckpointManager(tmp_path, n_hosts=4)
    state = {"params": {"w": np.arange(64, dtype=np.float32).reshape(8, 8)}}
    assert m4.save(5, state)
    m2 = CheckpointManager(tmp_path, n_hosts=2)
    step, got = m2.restore()
    assert step == 5
    np.testing.assert_array_equal(got["params"]["w"], state["params"]["w"])


def test_manager_versioned_updates(tmp_path):
    m = CheckpointManager(tmp_path)
    s1 = {"params": {"w": np.zeros(4, np.float32)}}
    s2 = {"params": {"w": np.ones(4, np.float32)}}
    assert m.save(1, s1)
    assert m.save(2, s2)
    step, got = m.restore()
    assert step == 2
    np.testing.assert_array_equal(got["params"]["w"], np.ones(4))


def test_async_manager_overlap(tmp_path):
    m = AsyncCheckpointManager(tmp_path)
    state = {"params": {"w": np.arange(8, dtype=np.float32)}}
    m.save_async(1, state)
    # mutate the live state after snapshot: committed bytes must be the
    # snapshot, proving the copy decouples training from the commit
    state["params"]["w"] += 100
    m.close()
    step, got = m.restore()
    assert step == 1
    np.testing.assert_array_equal(got["params"]["w"],
                                  np.arange(8, dtype=np.float32))

"""repro.obs v2 — the causal-lifecycle / provenance / SLO contracts:

- every persist fence carries a ``(component, reason)`` provenance
  label (outermost frame names the business initiator, innermost the
  mechanical cause) and fences over already-clean lines are flagged
  redundant — zero on the group-commit hot path, honestly nonzero on
  the per-op protocol's conservative read barrier;
- ops carry a stable ``op_id`` from submit through requeue to
  completion, and their latency decomposes into
  ``queue_us + dispatch_us + persist_us == latency_us`` exactly;
- the SpanTracer counts EVERY dropped event (ring overflow and
  enable-time shrink) in both its own ledger and the registry
  ``spans_dropped`` counter, and an overflowed buffer still exports a
  schema-valid Chrome trace;
- SloSpecs evaluate over sliding windows with multi-window burn rates
  and the report validates against the ``SLO_<section>.json`` schema.
"""
import dataclasses
import json
import threading

import pytest

from repro.obs import (SloEngine, SloSpec, SpanTracer, chrome_trace,
                       current_flush_reason, disable_tracing,
                       enable_tracing, export_jsonl, flush_reason,
                       get_registry, get_tracer, reset_metrics, span,
                       span_tree, validate_chrome_trace,
                       validate_slo_report)
from repro.service import KVService
from repro.structures import KVOp


@pytest.fixture(autouse=True)
def _quiesce_obs():
    """Leave the process-global tracer/registry clean for other tests."""
    yield
    disable_tracing()
    get_tracer().clear()
    reset_metrics()


# -- flush provenance ----------------------------------------------------------

def test_flush_reason_outermost_component_innermost_reason():
    assert current_flush_reason() == ("pmem", "unattributed")
    with flush_reason("service", "journal_decide"):
        assert current_flush_reason() == ("service", "journal_decide")
        with flush_reason("committer", "descriptor"):
            # business initiator (outermost) + mechanical cause (innermost)
            assert current_flush_reason() == ("service", "descriptor")
        assert current_flush_reason() == ("service", "journal_decide")
    assert current_flush_reason() == ("pmem", "unattributed")


def test_flush_reason_is_thread_local():
    seen = {}

    def worker():
        seen["worker"] = current_flush_reason()

    with flush_reason("structures", "doubling_pump"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    assert seen["worker"] == ("pmem", "unattributed")


def test_pmem_redundant_fence_detection(tmp_path):
    from repro import PMemPool
    pool = PMemPool(tmp_path)
    reg = get_registry()
    with flush_reason("test", "first_write"):
        pool.write_persist("a.bin", b"x")       # dirty line: real fence
    assert reg.value("flush_fences", component="test",
                     reason="first_write") == 1
    assert reg.total("redundant_fences") == 0
    with flush_reason("test", "paranoia"):
        pool.persist("a.bin")                   # clean line: redundant
    assert reg.value("redundant_fences", component="test",
                     reason="paranoia") == 1
    # durable delete of a file that never existed is redundant too
    with flush_reason("test", "ghost_delete"):
        pool.delete_persist("never_there.bin")
    assert reg.value("redundant_fences", component="test",
                     reason="ghost_delete") == 1
    # deleting a real durable file is NOT redundant
    with flush_reason("test", "real_delete"):
        pool.delete_persist("a.bin")
    assert reg.total("redundant_fences") == 2
    assert reg.value("flush_fences", component="test",
                     reason="real_delete") == 1


def _drive_durable_service(group_commit: bool, n_ops: int = 24):
    svc = KVService(2, structure="hashmap", backend="durable",
                    n_buckets=32, round_cap=4, group_commit=group_commit)
    svc.apply([KVOp("insert", k, k + 1) for k in range(1, 13)])
    svc.reset_stats()                    # window start: registry zeroed
    for i in range(n_ops):
        svc.submit(KVOp("update", 1 + (i % 12), i + 100), client=i % 4)
    svc.drain()
    return svc


def test_group_commit_hot_path_zero_redundant_fences():
    _drive_durable_service(group_commit=True)
    reg = get_registry()
    assert reg.total("flush_fences") > 0, "window issued no fences at all"
    assert reg.total("redundant_fences") == 0, (
        "the coalesced group-commit path issued a redundant fence — "
        "the instruction class the paper removes is back")


def test_per_op_read_barrier_pays_redundant_fences_with_labels():
    _drive_durable_service(group_commit=False)
    reg = get_registry()
    assert reg.total("redundant_fences") > 0, (
        "the per-op read barrier should fence steady-state clean slot "
        "lines; the redundancy detector is dead")
    # the redundant fences are attributed to the barrier, by label
    assert reg.value("redundant_fences", component="committer",
                     reason="read_barrier") > 0
    # the taxonomy is present on the real fences too
    for reason in ("data_prepare", "reserve"):
        assert reg.value("flush_fences", component="committer",
                         reason=reason) > 0, reason


# -- op lifecycle: op_id threading + latency partition -------------------------

def test_op_lifecycle_instants_and_breakdown_identity():
    svc = KVService(2, structure="hashmap", n_buckets=32, round_cap=2)
    svc.apply([KVOp("insert", k, k) for k in range(1, 9)])
    svc.reset_stats()
    enable_tracing().clear()
    try:
        futs = [svc.submit(KVOp("update", 1 + (i % 8), i + 100), client=0)
                for i in range(12)]
        svc.drain()
    finally:
        disable_tracing()
    assert all(f.done for f in futs)
    events = get_tracer().events()
    by_name = {}
    for e in events:
        by_name.setdefault(e["name"], []).append(e)
    submits = {e["args"]["op_id"] for e in by_name["op.submit"]}
    completes = {e["args"]["op_id"] for e in by_name["op.complete"]}
    # every submitted op completed under the SAME op_id
    assert submits == completes == {f.op_id for f in futs}
    # a round_cap of 2 with 12 ops on 2 shards forces requeues; each
    # requeue instant names the op it deferred
    if "op.requeue" in by_name:
        assert {e["args"]["op_id"]
                for e in by_name["op.requeue"]} <= submits
    # the breakdown partitions latency per completion event, exactly
    # (args are rounded to 0.1us, so allow the rounding slack)
    for e in by_name["op.complete"]:
        a = e["args"]
        total = a["queue_us"] + a["dispatch_us"] + a["persist_us"]
        assert total == pytest.approx(a["latency_us"], abs=0.3)
    # and the histograms carry the same partition in aggregate
    st = svc.stats
    assert st.queue_us.count == st.latency_us.count
    parts = (st.queue_us.mean_us + st.dispatch_us.mean_us
             + st.persist_us.mean_us)
    assert parts == pytest.approx(st.latency_us.mean_us, rel=0.02)


def test_durable_service_attributes_persist_share():
    svc = _drive_durable_service(group_commit=True)
    st = svc.stats
    assert st.persist_us.count > 0
    assert st.persist_us.total_us > 0, (
        "durable waves fence to disk; the persist_us leg of the "
        "breakdown must be nonzero")
    assert (st.queue_us.mean_us + st.dispatch_us.mean_us
            + st.persist_us.mean_us) == pytest.approx(
        st.latency_us.mean_us, rel=0.02)
    # the registry mirrors the same series for the bench windows
    assert get_registry().histogram(
        "persist_us", component="service").count == st.persist_us.count


def test_retry_waves_histogram_counts_split_retries():
    # retry_waves counts executed-and-lost rounds plus split retries
    # (scheduling defers recompile for free) — a tiny-leaf BzTree under
    # an insert burst forces splits, so some op must retry its wave
    svc = KVService(1, structure="bztree", leaf_cap=4, root_cap=16,
                    n_regions=24, round_cap=4)
    svc.reset_stats()
    for i in range(16):
        svc.submit(KVOp("insert", 10 + i, 1000 + i), client=i % 4)
    svc.drain()
    st = svc.stats
    assert st.retry_waves.count == st.completed
    assert st.retry_waves.max_us >= 1, (
        "16 inserts through 4-entry leaves must split and retry someone")
    assert get_registry().histogram(
        "retry_waves", component="service").count == st.completed


# -- SpanTracer drop accounting ------------------------------------------------

def test_ring_overflow_counts_drops_in_both_ledgers_and_exports():
    reset_metrics()
    tracer = SpanTracer(capacity=8)
    tracer.enable()
    for i in range(20):
        with tracer.span(f"s{i}"):
            pass
    assert len(tracer) == 8
    assert tracer.dropped == 12
    assert get_registry().value("spans_dropped", component="obs") == 12
    # an overflowed buffer still exports a schema-valid Chrome trace
    # that reports what it lost
    obj = chrome_trace(tracer)
    validate_chrome_trace(obj)
    assert obj["otherData"]["dropped_events"] == 12


def test_enable_shrink_counts_discarded_events():
    reset_metrics()
    tracer = SpanTracer(capacity=16)
    tracer.enable()
    for i in range(10):
        with tracer.span(f"s{i}"):
            pass
    # shrinking below the buffered count used to lose events SILENTLY;
    # now the 6 oldest land in both drop ledgers
    tracer.enable(capacity=4)
    assert len(tracer) == 4
    assert tracer.dropped == 6
    assert get_registry().value("spans_dropped", component="obs") == 6
    assert [e["name"] for e in tracer.events()] == \
        ["s6", "s7", "s8", "s9"]
    validate_chrome_trace(chrome_trace(tracer))


# -- exporters over gnarly traces ----------------------------------------------

def test_export_jsonl_round_trip(tmp_path):
    tracer = SpanTracer(capacity=64)
    tracer.enable()
    with tracer.span("outer", layer=1):
        with tracer.span("inner"):
            pass
        tracer.instant("mark", k="v")
    path = export_jsonl(tmp_path / "events.jsonl", tracer)
    lines = path.read_text().splitlines()
    parsed = [json.loads(ln) for ln in lines]
    assert parsed == tracer.events()
    # buffer order: inner closes first, instants interleave faithfully
    assert [e["name"] for e in parsed] == ["inner", "mark", "outer"]


def test_span_tree_nested_cross_thread_with_dropped_gap():
    tracer = SpanTracer(capacity=6)      # tight: the gap is real
    tracer.enable()

    def worker():
        with tracer.span("w.outer"):
            with tracer.span("w.inner"):
                pass

    with tracer.span("main.outer"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
        for i in range(6):               # push the oldest events out
            with tracer.span("main.child"):
                pass
    tree = span_tree(tracer.events())
    # nesting is per thread: the worker's stack never nests under main's
    assert tree.get("w.outer", []) == ["w.inner"] or \
        "w.outer" not in tree            # w.* may have fallen off the ring
    assert "main.child" in tree.get("main.outer", [])
    assert "w.inner" not in tree.get("main.outer", [])
    assert tracer.dropped > 0            # the gap actually happened
    validate_chrome_trace(chrome_trace(tracer))


# -- SLO engine ----------------------------------------------------------------

def test_slo_spec_kinds_and_validation():
    ceil = SloSpec("lat", "p99_us", 100.0, "ceiling")
    floor = SloSpec("tput", "ops", 10.0, "floor")
    assert ceil.violated(101.0) and not ceil.violated(100.0)
    assert floor.violated(9.0) and not floor.violated(10.0)
    with pytest.raises(ValueError):
        SloSpec("bad", "m", 1.0, "sideways")
    with pytest.raises(ValueError):
        SloSpec("bad", "m", 1.0, "ceiling", error_budget=1.0)


def test_slo_multi_window_burn_fires_only_on_both():
    spec = SloSpec("lat", "p99_us", 100.0, "ceiling", error_budget=0.25)
    eng = SloEngine([spec], short_window=4, long_window=16)
    # long history of good samples, then a short burst of violations:
    # short window burns, long window stays within budget -> still ok
    for _ in range(14):
        eng.observe({"p99_us": 50.0})
    for _ in range(2):
        eng.observe({"p99_us": 500.0})
    res = eng.evaluate()[0]
    assert res["burn_short"] >= 1.0 and res["burn_long"] < 1.0
    assert res["ok"]
    # sustained violations burn both windows -> fires
    for _ in range(16):
        eng.observe({"p99_us": 500.0})
    res = eng.evaluate()[0]
    assert res["burn_short"] >= 1.0 and res["burn_long"] >= 1.0
    assert not res["ok"]


def test_slo_missing_metric_reports_zero_evaluations():
    eng = SloEngine([SloSpec("ghost", "nope_us", 1.0, "ceiling")])
    eng.observe({"something_else": 5.0})
    res = eng.evaluate()[0]
    assert res["evaluations"] == 0 and res["ok"]
    assert "last" not in res


def test_slo_report_validates_and_rejects_malformed():
    eng = SloEngine([SloSpec("lat", "p99_us", 100.0, "ceiling",
                             error_budget=0.1)])
    eng.observe({"p99_us": 50.0})
    doc = validate_slo_report(eng.report(section="unit", quick=True))
    assert doc["section"] == "unit" and doc["observations"] == 1
    bad = json.loads(json.dumps(doc))
    bad["specs"][0]["violations"] = 99       # > evaluations
    with pytest.raises(ValueError):
        validate_slo_report(bad)
    with pytest.raises(ValueError):
        validate_slo_report({"specs": [], "ok": "yes",
                             "observations": 0,
                             "windows": {"short": 1, "long": 1}})


# -- chaos: SLOs evaluated during the fault schedule ---------------------------

def test_chaos_scenario_carries_in_run_slo_verdict(tmp_path):
    from repro.chaos import default_scenarios, run_scenario
    sc = next(s for s in default_scenarios(seed=3, waves=8)
              if s.backend == "durable")
    sc = dataclasses.replace(sc, waves=8)
    rep = run_scenario(sc, durable_root=str(tmp_path / "pm"))
    assert rep.slo is not None
    validate_slo_report(rep.slo)
    assert rep.slo["section"] == f"chaos.{sc.family}"
    evals = sum(s["evaluations"] for s in rep.slo["specs"])
    assert evals > 0, "SLOs were never evaluated during the waves"
    assert rep.slo["observations"] == rep.waves_run


def test_chaos_fault_injections_are_trace_instants(tmp_path):
    from repro.chaos import default_scenarios, run_scenario
    sc = next(s for s in default_scenarios(seed=0)
              if s.family == "hot_key_storm")
    enable_tracing().clear()
    try:
        rep = run_scenario(sc, durable_root=(
            str(tmp_path / "pm") if sc.backend == "durable" else None))
    finally:
        disable_tracing()
    assert rep.faults_fired > 0
    faults = [e for e in get_tracer().events()
              if e["name"] == "chaos.fault"]
    assert faults, "faults fired but no chaos.fault instant was traced"
    assert all(e["ph"] == "i" and "kind" in e["args"] for e in faults)

"""Round-level group commit + cached stacked dispatch (DESIGN.md Sec. 9).

The tentpole invariants:

- a coalesced round commits under ONE persist fence, and a crash at
  EVERY persist of the coalesced path recovers to either "round
  invisible" (record absent) or "round fully applied" (record durable →
  redo) — never a torn round;
- pruning a round record first flushes the state it guards, so the
  durable truth never has a gap;
- the stacked kernel dispatch never retraces across same-bucket
  steady-state rounds (the trace cache survives stats resets).
"""
import numpy as np
import pytest

from repro import Committer, MarkerCommitter, PMemPool, SimulatedCrash
from repro.pmwcas import (DurabilityStats, DurableBackend, KernelBackend,
                          MwCASOp)
from repro.service import (BatchScheduler, CrossShardJournal, KVService,
                           ShardRouter, StackedKernelExecutor)
from repro.structures import (INSERT, KVOp, UPDATE,
                              check_durable_crash_sweep)


# ---------------------------------------------------------------------------
# committer: the round protocol itself
# ---------------------------------------------------------------------------

def test_commit_round_one_fence_and_verdicts(tmp_path):
    pool = PMemPool(tmp_path)
    c = Committer(pool)
    p0 = pool.persist_count
    ok = c.commit_round(
        [("a1", [("x", 0, 1), ("y", 0, 2)]),
         ("a2", [("z", 0, 3)]),
         ("a3", [("x", 0, 7)]),          # collides with a1 -> loses
         ("a4", [("w", 5, 6)])],         # stale expected -> loses
        {"x": b"X1", "y": b"Y2", "z": b"Z3", "w": b"W6"})
    assert ok == [True, True, False, False]
    assert pool.persist_count - p0 == 1          # the single round fence
    assert (c.slot_version("x"), c.slot_version("y"),
            c.slot_version("z"), c.slot_version("w")) == (1, 2, 3, 0)
    assert pool.read("data/x.v1.bin") == b"X1"
    s = c.stats
    assert s.fences == 1 and s.round_commits == 1 and s.ops_committed == 2
    assert s.flushes_issued == 1
    # two winners would have paid (3*2+2) + (3*1+2) = 13 per-op persists
    assert s.flushes_saved == 12


def test_commit_round_no_op_versions_fail(tmp_path):
    c = Committer(PMemPool(tmp_path))
    assert c.commit_round([("a", [("x", 0, 0)])], {"x": b"p"}) == [False]
    assert c.slot_version("x") == 0


def test_round_records_replay_in_commit_order(tmp_path):
    """Two durable round records advancing the same slot, finalize
    writes lost to the crash: replay must run in commit order or the
    second round's expected values never match."""
    pool = PMemPool(tmp_path)
    c = Committer(pool)
    assert c.commit_round([("a", [("x", 0, 1)])], {"x": b"v1"}) == [True]
    assert c.commit_round([("b", [("x", 1, 2)])], {"x": b"v2"}) == [True]
    crashed = pool.crash()                  # drops every lazy slot write
    c2 = Committer(crashed)
    c2.recover()
    assert c2.slot_version("x") == 2
    assert crashed.read("data/x.v2.bin") == b"v2"


def test_prune_flushes_round_effects_before_dropping(tmp_path):
    """The round record is the only durable copy of its effects; prune
    must flush slots+data first or a later crash loses committed
    state."""
    pool = PMemPool(tmp_path)
    c = Committer(pool)
    c.commit_round([("a", [("x", 0, 1), ("y", 0, 2)])],
                   {"x": b"X", "y": b"Y"})
    assert c.prune_completed() == 1
    assert pool.listdir("wal") == []
    c2 = Committer(pool.crash())
    c2.recover()
    assert c2.slot_version("x") == 1 and c2.slot_version("y") == 2
    assert pool.read("data/x.v1.bin") == b"X"


def test_prune_before_recover_redoes_rounds_first(tmp_path):
    """Prune is safe at ANY point, including on a reopened pool before
    recover(): the visible slot state may still predate a durable round
    record (the lazy finalize writes died with the process), and prune
    must redo the round before flushing and dropping its only durable
    copy — or the committed op is lost forever."""
    pool = PMemPool(tmp_path)
    c = Committer(pool)
    assert c.commit_round([("op1", [("x", 0, 1)])],
                          {"x": b"payload-v1"}) == [True]
    # process dies: lazy writes gone, the round record alone survives
    reopened = pool.crash()
    c2 = Committer(reopened)
    assert c2.prune_completed() == 1          # NO recover() first
    assert c2.slot_version("x") == 1
    assert reopened.read("data/x.v1.bin") == b"payload-v1"
    # and the state is durable: a further crash/recover is a fixpoint
    c3 = Committer(reopened.crash())
    c3.recover()
    assert c3.slot_version("x") == 1


def test_marker_committer_opts_out_of_group_commit(tmp_path):
    b = DurableBackend(pool=PMemPool(tmp_path), committer="marker",
                       group_commit=True)
    assert not b.group_commit               # markers are per-slot by design
    (r,) = b.execute([MwCASOp([("x", 0, 1)])])
    assert r.success and b.read("x") == 1
    assert isinstance(b.committer, MarkerCommitter)
    assert b.durability_stats.op_commits == 1


def test_group_commit_flag_survives_crash(tmp_path):
    b = DurableBackend(pool=PMemPool(tmp_path), group_commit=True)
    assert b.crash().group_commit
    b2 = DurableBackend(pool=PMemPool(tmp_path / "b"), group_commit=False)
    assert not b2.crash().group_commit


# ---------------------------------------------------------------------------
# the crash window of a coalesced round: crash at every persist
# ---------------------------------------------------------------------------

def test_coalesced_round_crashes_atomically(tmp_path):
    """Crash at every persist through TWO multi-op rounds driven
    straight through DurableBackend.execute.  Every recovered state
    must be a round PREFIX: a round is invisible (its record never
    became durable) or fully applied (record durable -> redo) — ops of
    one round never land separately."""
    round1 = [MwCASOp([("a", 0, 1), ("b", 0, 2)]),
              MwCASOp([("c", 0, 3)])]
    round2 = [MwCASOp([("a", 1, 4)]),
              MwCASOp([("d", 0, 5), ("e", 0, 6)])]
    states = {  # slot values after 0, 1, 2 committed rounds
        0: (0, 0, 0, 0, 0),
        1: (1, 2, 3, 0, 0),
        2: (4, 2, 3, 5, 6),
    }
    crash_at = 0
    seen = set()
    while True:
        pool = PMemPool(tmp_path / f"c{crash_at}",
                        crash_after_persists=crash_at)
        b = DurableBackend(pool=pool)
        committed = 0
        crashed = False
        try:
            assert all(r.success for r in b.execute(round1))
            committed = 1
            assert all(r.success for r in b.execute(round2))
            committed = 2
        except SimulatedCrash:
            crashed = True
        rec = b.crash()
        got = tuple(rec.read(n) for n in "abcde")
        allowed = [states[k] for k in range(committed, 3)]
        assert got in allowed, (crash_at, got, allowed)
        seen.add(got)
        # a second crash/recover cycle is a fixpoint
        rec2 = rec.crash()
        assert tuple(rec2.read(n) for n in "abcde") == got, crash_at
        if not crashed:
            assert got == states[2]
            # both torn-round outcomes actually occurred across the sweep
            assert states[0] in seen and states[2] in seen
            return
        crash_at += 1
        assert crash_at < 50, "sweep did not terminate"


def test_structure_sweep_through_batched_rounds(tmp_path):
    """The extended checker: a hash-map workload applied in BATCHES, so
    the coalesced path commits real multi-op rounds, swept crash-at-
    every-persist (including prune + second recovery in the checker's
    teardown)."""
    ops = [KVOp(INSERT, 5, 100), KVOp(INSERT, 7, 200),
           KVOp(INSERT, 9, 300), KVOp(UPDATE, 5, 111),
           KVOp(INSERT, 12, 400), KVOp(UPDATE, 7, 222)]
    n = check_durable_crash_sweep(ops, n_buckets=8, root=tmp_path,
                                  group_commit=True, batch=3)
    assert n >= 2                  # one fence per batch round (+ teardown)


def test_scheduler_round_is_one_fence_per_durable_shard(tmp_path):
    """Service rounds map 1:1 onto commit fences: a wave over durable
    shards pays exactly one persist per shard round, not one per op."""
    pools = [PMemPool(tmp_path / f"s{i}") for i in range(2)]
    backends = [DurableBackend(pool=p) for p in pools]
    sched = BatchScheduler(backends, ShardRouter(2, words_per_shard=8),
                           round_cap=8)
    ops = [MwCASOp([(a, 0, 1)]) for a in (0, 1, 2)] + \
          [MwCASOp([(8 + a, 0, 1)]) for a in (0, 1, 2, 3)]
    p0 = sum(p.persist_count for p in pools)
    futs = sched.submit_many(ops)
    sched.drain()
    assert all(f.success for f in futs)
    assert sum(p.persist_count for p in pools) - p0 == 2   # one per shard
    d = sched.durability_stats()
    assert d.fences == 2 and d.ops_committed == 7
    assert d.flushes_saved == (3 * 5 - 1) + (4 * 5 - 1)


# ---------------------------------------------------------------------------
# cached stacked dispatch: the retrace counters
# ---------------------------------------------------------------------------

def _kernel_rounds(n_shards, words, wave, b_per_shard, k):
    """One wave of same-bucket rounds: b_per_shard ops of width k per
    shard, fresh addresses per wave so every op wins."""
    rounds = {}
    for s in range(n_shards):
        ops = []
        for i in range(b_per_shard):
            base = (wave * b_per_shard + i) * k
            ops.append(MwCASOp([((base + j) % words, 0, 1)
                                for j in range(k)]).sorted())
        rounds[s] = ops
    return rounds


def test_stacked_dispatch_zero_retraces_across_steady_state():
    n_shards, words = 4, 64
    backends = [KernelBackend(n_words=words, use_kernel=False)
                for _ in range(n_shards)]
    ex = StackedKernelExecutor(round_cap=4)
    ex.execute(backends, _kernel_rounds(n_shards, words, 0, 3, 2))
    assert ex.stats.traces == 1 and ex.stats.hits == 0
    for wave in range(1, 6):
        # varying B (<= cap) and varying shard subsets stay in-bucket
        rounds = _kernel_rounds(n_shards, words, wave, 1 + wave % 3, 2)
        if wave % 2:
            rounds.pop(wave % n_shards)        # a shard sits this wave out
        ex.execute(backends, rounds)
    assert ex.stats.traces == 1                # zero steady-state retraces
    assert ex.stats.hits == 5
    assert ex.stats.dispatches == 6
    # a genuinely new bucket (wider K) does retrace, once
    ex.execute(backends, _kernel_rounds(n_shards, words, 9, 2, 3))
    ex.execute(backends, _kernel_rounds(n_shards, words, 11, 2, 3))
    assert ex.stats.traces == 2 and ex.stats.hits == 6


def test_stacked_dispatch_with_idle_shards_matches_serial():
    """Shape stability stacks ALL kernel shards — shards without a round
    ride along as padding and their tables must come back unchanged."""
    n_shards, words = 4, 16
    stacked = [KernelBackend(n_words=words, use_kernel=False)
               for _ in range(n_shards)]
    serial = [KernelBackend(n_words=words, use_kernel=False)
              for _ in range(n_shards)]
    ex = StackedKernelExecutor(round_cap=4)
    rounds = {0: [MwCASOp([(1, 0, 5)])], 2: [MwCASOp([(3, 0, 7)])]}
    out = ex.execute(stacked, rounds)
    assert set(out) == {0, 2} and out[0] == [True] and out[2] == [True]
    for s, ops in rounds.items():
        serial[s].execute(ops)
    for a, b in zip(stacked, serial):
        assert np.array_equal(a.values(), b.values())


def test_kvservice_steady_state_waves_never_retrace():
    """The acceptance counter: after warmup (load phase), a measurement
    window of same-bucket waves recompiles NOTHING — reset_stats zeroes
    the counters but keeps the trace cache warm."""
    svc = KVService(4, structure="hashmap", n_buckets=32, round_cap=4)
    svc.apply([KVOp(INSERT, k, k) for k in range(1, 33)])      # warmup
    svc.reset_stats()
    svc.apply([KVOp(UPDATE, k, k + 100) for k in range(1, 33)])
    d = svc.stats.dispatch
    assert d is not None
    assert d.traces == 0, f"steady-state retraces: {d}"
    assert d.hits == d.dispatches > 0
    assert svc.stats.as_row()["traces"] == 0


def test_serial_executor_counts_rounds():
    svc = KVService(1, structure="hashmap", n_buckets=16, round_cap=4)
    svc.apply([KVOp(INSERT, k, k) for k in range(1, 9)])
    d = svc.stats.dispatch
    assert d is not None and d.serial_rounds > 0 and d.dispatches == 0


# ---------------------------------------------------------------------------
# journal prune cadence (the ROADMAP satellite)
# ---------------------------------------------------------------------------

def test_journal_prunes_on_cadence_and_stays_bounded(tmp_path):
    pool = PMemPool(tmp_path / "j")
    backends = [KernelBackend(n_words=8, use_kernel=False)
                for _ in range(2)]
    sched = BatchScheduler(backends, ShardRouter(2, words_per_shard=8),
                           journal=CrossShardJournal(pool),
                           journal_prune_every=4)
    journal_sizes = []
    val = {0: 0, 8: 0}
    for i in range(16):
        fut = sched.submit(MwCASOp([(0, val[0], val[0] + 1),
                                    (8, val[8], val[8] + 1)]))
        sched.drain()
        assert fut.success
        val[0] += 1
        val[8] += 1
        journal_sizes.append(len(sched.journal))
    # pruned every 4 global rounds: the journal never exceeds the cadence
    assert max(journal_sizes) <= 4
    assert sched.stats.journal_pruned >= 12
    # long-running regression: the size saw-tooths instead of growing —
    # every cadence boundary (rounds 4, 8, 12, 16) drops to zero
    assert [journal_sizes[i] for i in (3, 7, 11, 15)] == [0, 0, 0, 0]


def test_journal_prune_cadence_zero_disables(tmp_path):
    pool = PMemPool(tmp_path / "j")
    backends = [KernelBackend(n_words=8, use_kernel=False)
                for _ in range(2)]
    sched = BatchScheduler(backends, ShardRouter(2, words_per_shard=8),
                           journal=CrossShardJournal(pool),
                           journal_prune_every=0)
    for i in range(6):
        sched.submit(MwCASOp([(0, i, i + 1), (8, i, i + 1)]))
        sched.drain()
    assert len(sched.journal) == 6 and sched.stats.journal_pruned == 0
    with pytest.raises(ValueError):
        BatchScheduler(backends, ShardRouter(2, words_per_shard=8),
                       journal_prune_every=-1)


# ---------------------------------------------------------------------------
# stats plumbing
# ---------------------------------------------------------------------------

def test_durability_stats_merge_and_row():
    a = DurabilityStats(flushes_issued=2, flushes_saved=10, fences=1,
                        round_commits=1, op_commits=0, ops_committed=3)
    b = DurabilityStats(flushes_issued=1, flushes_saved=5, fences=1,
                        round_commits=1, op_commits=2, ops_committed=4)
    merged = DurabilityStats().merge(a).merge(b)
    assert merged.flushes_issued == 3 and merged.flushes_saved == 15
    assert merged.ops_committed == 7
    assert merged.as_row()["fences"] == 2
    assert abs(merged.flushes_per_commit - 3 / 7) < 1e-12


def test_kvservice_durability_stats_none_for_kernel_shards():
    svc = KVService(2, structure="hashmap", n_buckets=8)
    assert svc.durability_stats() is None

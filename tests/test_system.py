"""End-to-end behaviour tests for the whole system: fault-tolerant trainer,
atomic multi-group checkpoints, serving admission, data determinism."""
import numpy as np
import pytest

from repro.configs.base import LayerSpec, ModelConfig
from repro.data.synthetic import DataConfig, SyntheticStream
from repro.models import build_model
from repro.optim import adamw
from repro.runtime import Trainer, TrainerConfig

CFG = ModelConfig(name="sys-test", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=4, d_ff=256, vocab=512,
                  unit=(LayerSpec(kind="attn", ffn="dense"),))


def _trainer(tmp, steps=40, ckpt_every=10, ckpt_async=False):
    return Trainer(
        build_model(CFG),
        adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=steps,
                          weight_decay=0.0),
        DataConfig(vocab=CFG.vocab, seq_len=64, global_batch=4),
        TrainerConfig(total_steps=steps, ckpt_every=ckpt_every,
                      ckpt_async=ckpt_async, ckpt_dir=str(tmp)),
    )


def test_training_reduces_loss(tmp_path):
    t = _trainer(tmp_path / "a")
    _, _, losses = t.run()
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


def test_crash_restart_matches_uninterrupted_run(tmp_path):
    """Crash mid-run; the restarted run must match an uninterrupted run
    exactly (same data order, same updates) — torn state is impossible."""
    t1 = _trainer(tmp_path / "crash")
    with pytest.raises(RuntimeError):
        t1.run(crash_at_step=24)
    t2 = _trainer(tmp_path / "crash")
    params_c, _, _ = t2.run()

    t3 = _trainer(tmp_path / "ref")
    params_r, _, _ = t3.run()
    a = np.asarray(params_c["units"]["layer0"]["attn"]["wq"])
    b = np.asarray(params_r["units"]["layer0"]["attn"]["wq"])
    np.testing.assert_array_equal(a, b)


def test_async_checkpointing_run(tmp_path):
    t = _trainer(tmp_path / "async", ckpt_async=True)
    _, _, losses = t.run()
    assert losses[-1] < losses[0]
    # a committed checkpoint exists and restores at the final step
    t2 = _trainer(tmp_path / "async")
    _, _, stream, start = t2.restore_or_init()
    assert start == 40


def test_data_stream_deterministic_and_checkpointable():
    import dataclasses
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=4)
    s1 = SyntheticStream(cfg)
    batches = [s1.next_batch() for _ in range(5)]
    s2 = SyntheticStream.from_state(cfg, {"seed": 0, "step": 3})
    np.testing.assert_array_equal(s2.next_batch()["tokens"],
                                  batches[3]["tokens"])
    c2 = dataclasses.replace(cfg, n_hosts=2, host_id=1)
    sh = SyntheticStream(c2)
    assert not np.array_equal(sh.next_batch()["tokens"][:2],
                              batches[0]["tokens"][:2])


def test_serve_admission_all_or_nothing():
    from repro.launch.serve import PageAllocator
    alloc = PageAllocator(16)
    reqs = np.asarray([[0, 1, 2, 3],
                       [2, 3, 4, 5],     # overlaps with request 0 -> loses
                       [6, 7, 8, 9]], np.int32)
    granted = alloc.admit(reqs)
    assert granted.tolist() == [True, False, True]
    free = np.asarray(alloc.free)
    assert free[[0, 1, 2, 3, 6, 7, 8, 9]].sum() == 0
    assert free[[4, 5]].sum() == 2  # the loser claimed nothing

    alloc.release([0, 1, 2, 3])
    granted2 = alloc.admit(np.asarray([[2, 3, 4, 5]], np.int32))
    assert granted2.tolist() == [True]


def test_straggler_monitor_runs(tmp_path):
    t = _trainer(tmp_path / "s", steps=12)
    t.run()
    assert len(t.step_times) == 12
    assert t.stragglers <= 3

"""repro.service — sharded, batched PMwCAS execution service.

Covers the router bijections, the conflict-defer scheduling rule, the
stacked-vs-serial kernel dispatch differential, cross-shard
serialization and its crash atomicity (the decision-journal redo), and
the KVService front against a single-structure reference.
"""
import pathlib

import numpy as np
import pytest

from repro import PMemPool, SimulatedCrash
from repro.pmwcas import (DurableBackend, KernelBackend, MwCASOp, SimBackend,
                          make_backend, register_backend)
from repro.service import (BatchScheduler, CROSS_SHARD, CrossShardJournal,
                           KVService, SerialShardExecutor, ServiceError,
                           ShardRouter, StackedKernelExecutor, build_rounds,
                           select_executor)
from repro.structures import (FULL, HashMap, INSERT, KVOp, OK, WorkloadSpec,
                              client_streams, compile_workload, interleave,
                              load_phase, partition_ops, replay_effects)


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------

def test_router_range_and_hash_are_bijections():
    for policy in ("range", "hash"):
        r = ShardRouter(4, words_per_shard=8, policy=policy)
        seen = set()
        for addr in range(32):
            s, l = r.shard_of_addr(addr), r.local(addr)
            assert 0 <= s < 4
            assert r.global_addr(s, l) == addr
            seen.add((s, l))
        assert len(seen) == 32


def test_router_classify_local_and_cross():
    r = ShardRouter(4, words_per_shard=8)
    local = r.classify(MwCASOp([(8, 0, 1), (9, 1, 2)]))
    assert local.shard == 1 and not local.is_cross
    assert local.local.addrs == (0, 1)            # translated
    cross = r.classify(MwCASOp([(2, 0, 1), (9, 0, 1), (30, 0, 1)]))
    assert cross.is_cross and cross.shard == CROSS_SHARD
    assert set(cross.parts) == {0, 1, 3}
    assert cross.parts[3][0].addr == 6            # 30 -> shard 3, local 6


def test_router_rejects_bad_addresses():
    r = ShardRouter(2, words_per_shard=4)
    with pytest.raises(ValueError):
        r.shard_of_addr(8)                        # beyond shard space
    with pytest.raises(TypeError):
        r.classify(MwCASOp([("slot", 0, 1)]))
    with pytest.raises(ValueError):
        ShardRouter(2, policy="range")            # needs words_per_shard
    with pytest.raises(ValueError):
        ShardRouter(2, words_per_shard=4, policy="bogus")
    # hash policy bounds too: array shards silently drop out-of-range
    # scatters, so an unbounded address must be rejected up front
    rh = ShardRouter(2, words_per_shard=8, policy="hash")
    with pytest.raises(ValueError):
        rh.local(40)
    with pytest.raises(ValueError):
        rh.classify(MwCASOp([(16, 0, 1)]))


def test_scheduler_rejects_out_of_space_addresses():
    _, sched = _kernel_sched(n_shards=2, words=8)
    with pytest.raises(ValueError):
        sched.submit(MwCASOp([(40, 0, 1)]))       # would write nothing
    assert sched.pending_count == 0


def test_router_key_routing_spreads_and_is_stable():
    r = ShardRouter(4, words_per_shard=8)
    shards = [r.shard_of_key(k) for k in range(1, 257)]
    assert set(shards) == {0, 1, 2, 3}
    assert shards == [r.shard_of_key(k) for k in range(1, 257)]


# ---------------------------------------------------------------------------
# round formation: the conflict-defer rule
# ---------------------------------------------------------------------------

class _Entry:
    def __init__(self, op):
        self.local = op


def test_build_rounds_defers_duplicate_targets_and_caps():
    q = [_Entry(MwCASOp([(0, 0, 1)])), _Entry(MwCASOp([(1, 0, 1)])),
         _Entry(MwCASOp([(0, 1, 2)])),          # dup target -> defer
         _Entry(MwCASOp([(2, 0, 1)]))]
    rounds, leftovers, defers, overflows = build_rounds({0: q}, round_cap=2)
    assert [e.local.addrs for e in rounds[0]] == [(0,), (1,)]
    # the dup-target op deferred, the 4th op hit the cap
    assert [e.local.addrs for e in leftovers[0]] == [(0,), (2,)]
    assert defers[0] == 1 and overflows[0] == 1


# ---------------------------------------------------------------------------
# scheduler: conflict-defer, at-most-once, stats
# ---------------------------------------------------------------------------

def _kernel_sched(n_shards=2, words=8, round_cap=8, **kw):
    backends = [KernelBackend(n_words=words, use_kernel=False)
                for _ in range(n_shards)]
    router = ShardRouter(n_shards, words_per_shard=words)
    return backends, BatchScheduler(backends, router, round_cap=round_cap,
                                    **kw)


def test_scheduler_defer_then_definitive_verdict():
    _, sched = _kernel_sched()
    f1 = sched.submit(MwCASOp([(0, 0, 5)]))
    f2 = sched.submit(MwCASOp([(0, 0, 7)]))      # same target, same expected
    f3 = sched.submit(MwCASOp([(1, 0, 9)]))
    assert sched.step() == 2                     # f1 + f3; f2 deferred
    assert f1.success and f3.success and not f2.done
    assert sched.stats.shards[0].defers == 1
    assert sched.step() == 1                     # f2 executes, fails (a)
    assert f2.done and not f2.success
    assert f2.latency_rounds == 2 and f1.latency_rounds == 1
    assert sched.read(0) == 5 and sched.read(1) == 9
    # at-most-once: nothing left queued
    assert sched.pending_count == 0 and sched.step() == 0


def test_scheduler_matches_single_backend_reference():
    """Sharding must not change verdicts: disjoint per-shard traffic vs
    the same ops on one flat backend."""
    rng = np.random.default_rng(7)
    n_shards, words = 4, 8
    ops = []
    for _ in range(40):
        shard = int(rng.integers(n_shards))
        k = int(rng.integers(1, 3))
        addrs = sorted(rng.choice(words, size=k, replace=False).tolist())
        ops.append(MwCASOp([(shard * words + a, 0, 1 + int(rng.integers(4)))
                            for a in addrs]))
    backends, sched = _kernel_sched(n_shards, words)
    futs = sched.submit_many(ops)
    sched.drain()
    flat = KernelBackend(n_words=n_shards * words, use_kernel=False)
    # replay in completion order (the service's linearization) on the flat
    # table: every future's verdict must reproduce
    order = sorted(futs, key=lambda f: (f.latency_rounds, f.seq))
    for f in order:
        (ref,) = flat.execute([f.op])
        assert ref.success == f.success, f.op
    table = np.concatenate([b.values() for b in backends])
    assert np.array_equal(table, flat.values())


def test_scheduler_sim_shards_agree_with_kernel_shards():
    words, n_shards = 6, 2
    ops = [MwCASOp.increment([s * words + a], [0])
           for s in range(n_shards) for a in (0, 2, 4)]
    router = ShardRouter(n_shards, words_per_shard=words)
    sims = [SimBackend(words) for _ in range(n_shards)]
    s_sched = BatchScheduler(sims, router)
    kernels = [KernelBackend(n_words=words, use_kernel=False)
               for _ in range(n_shards)]
    k_sched = BatchScheduler(kernels, router)
    sf = s_sched.submit_many(ops)
    kf = k_sched.submit_many(ops)
    s_sched.drain(), k_sched.drain()
    assert [f.success for f in sf] == [f.success for f in kf] == [True] * 6
    for s in range(n_shards):
        assert np.array_equal(sims[s].values(), kernels[s].values())


def test_stacked_executor_matches_serial():
    rng = np.random.default_rng(3)
    n_shards, words = 4, 16

    def build(executor):
        backends = [KernelBackend(n_words=words, use_kernel=False)
                    for _ in range(n_shards)]
        sched = BatchScheduler(
            backends, ShardRouter(n_shards, words_per_shard=words),
            round_cap=4, executor=executor)
        return backends, sched

    ops = []
    for _ in range(60):
        shard = int(rng.integers(n_shards))
        k = int(rng.integers(1, 4))
        addrs = sorted(rng.choice(words, size=k, replace=False).tolist())
        ops.append(MwCASOp([(shard * words + a, 0, 1) for a in addrs]))
    stacked = StackedKernelExecutor()
    b1, s1 = build(stacked)
    b2, s2 = build(SerialShardExecutor())
    f1 = s1.submit_many(ops)
    f2 = s2.submit_many(ops)
    s1.drain(), s2.drain()
    assert [f.success for f in f1] == [f.success for f in f2]
    for x, y in zip(b1, b2):
        assert np.array_equal(x.values(), y.values())
    assert stacked.stacked_dispatches >= 1   # the vmapped path actually ran


def test_select_executor():
    kb = [KernelBackend(n_words=4, use_kernel=False) for _ in range(3)]
    assert isinstance(select_executor(kb), StackedKernelExecutor)
    assert isinstance(select_executor(kb[:1]), SerialShardExecutor)
    assert isinstance(select_executor([DurableBackend(), DurableBackend()]),
                      SerialShardExecutor)


# ---------------------------------------------------------------------------
# cross-shard ops: serialization + atomicity
# ---------------------------------------------------------------------------

def test_cross_shard_op_executes_atomically_and_serialized():
    _, sched = _kernel_sched(n_shards=3, words=8)
    flocal = sched.submit(MwCASOp([(0, 0, 1)]))
    fx = sched.submit(MwCASOp([(1, 0, 2), (9, 0, 3), (17, 0, 4)]))
    sched.drain()
    assert flocal.success and fx.success
    assert (sched.read(1), sched.read(9), sched.read(17)) == (2, 3, 4)
    assert sched.stats.cross_rounds == 1 and sched.stats.cross_ops == 1


def test_cross_shard_validation_failure_moves_nothing():
    _, sched = _kernel_sched(n_shards=2, words=8)
    sched.submit(MwCASOp([(9, 0, 7)]))
    sched.drain()
    fx = sched.submit(MwCASOp([(0, 0, 1), (9, 0, 2)]))   # 9 now holds 7
    sched.drain()
    assert fx.done and not fx.success
    assert sched.read(0) == 0 and sched.read(9) == 7


def test_two_cross_ops_in_one_global_round_serialize():
    _, sched = _kernel_sched(n_shards=2, words=8)
    fa = sched.submit(MwCASOp([(0, 0, 1), (8, 0, 1)]))
    fb = sched.submit(MwCASOp([(0, 0, 2), (8, 0, 2)]))   # same words
    sched.drain()
    assert fa.success and not fb.success      # b validated after a applied
    assert sched.read(0) == 1 and sched.read(8) == 1


# ---------------------------------------------------------------------------
# the decision journal
# ---------------------------------------------------------------------------

def test_journal_lifecycle(tmp_path):
    pool = PMemPool(tmp_path / "j")
    j = CrossShardJournal(pool)
    j.decide("x1", [(0, 1, 0, 5), (1, 2, 0, 6)])
    assert [r["id"] for r in j.pending()] == ["x1"]
    assert j.targets_of(j.pending()[0]) == [(0, 1, 0, 5), (1, 2, 0, 6)]
    j.complete("x1")
    assert j.pending() == [] and len(j) == 1
    assert j.prune() == 1 and len(j) == 0


def test_journal_torn_decision_record_is_dropped(tmp_path):
    pool = PMemPool(tmp_path / "j")
    pool.write("xwal/x9.json", b"{ not json")
    j = CrossShardJournal(pool)
    assert j.pending() == []                  # torn -> never decided


# ---------------------------------------------------------------------------
# crash during a sharded round (the satellite): a durable shard crashes
# at every persist of a mixed multi-shard batch
# ---------------------------------------------------------------------------

_W, _S = 8, 3


def _mixed_batch():
    return [
        MwCASOp([(0, 0, 1)]),                 # shard 0
        MwCASOp([(8, 0, 2)]),                 # shard 1
        MwCASOp([(16, 0, 3)]),                # shard 2
        MwCASOp([(1, 0, 4), (9, 0, 5)]),      # cross 0-1
        MwCASOp([(10, 0, 6), (17, 0, 7)]),    # cross 1-2
        MwCASOp([(2, 0, 8)]),                 # shard 0 again
    ]


_FINAL = {0: 1, 8: 2, 16: 3, 1: 4, 9: 5, 10: 6, 17: 7, 2: 8}
_CROSS_PAIRS = [[(1, 4), (9, 5)], [(10, 6), (17, 7)]]


def _crash_sweep(root: pathlib.Path, crash_shard, crash_journal,
                 group_commit=True):
    """Sweep crash points over the chosen pool; assert (i) client-
    committed ops survive, (ii) no cross-shard op is half-applied."""
    crash_at, swept = 0, 0
    while True:
        tag = f"c{crash_at}_"
        pools = [PMemPool(root / f"{tag}s{i}",
                          crash_after_persists=(
                              crash_at if i == crash_shard else None))
                 for i in range(_S)]
        backends = [DurableBackend(pool=p, group_commit=group_commit)
                    for p in pools]
        jpool = PMemPool(root / f"{tag}j",
                         crash_after_persists=(
                             crash_at if crash_journal else None))
        sched = BatchScheduler(
            backends, ShardRouter(_S, words_per_shard=_W), round_cap=4,
            journal=CrossShardJournal(jpool))
        futs = sched.submit_many(_mixed_batch())
        crashed = False
        try:
            sched.drain()
        except SimulatedCrash:
            crashed = True
        # recover: each crashed pool via its own WAL, then journal redo
        recovered = [b.crash() for b in backends]
        sched2 = BatchScheduler(
            recovered, ShardRouter(_S, words_per_shard=_W), round_cap=4,
            journal=CrossShardJournal(jpool.crash()))
        sched2.recover()
        for f in futs:                        # committed ops survive
            if f.done and f.success:
                for t in f.op.targets:
                    assert sched2.read(t.addr) == t.desired, \
                        (crash_at, f.op)
        for pairs in _CROSS_PAIRS:            # never half-applied
            vals = [sched2.read(a) for a, _d in pairs]
            assert vals == [d for _a, d in pairs] or vals == [0, 0], \
                (crash_at, pairs, vals)
        swept += 1
        if not crashed:
            for addr, val in _FINAL.items():  # clean run: everything landed
                assert sched2.read(addr) == val
            return swept
        crash_at += 1
        assert crash_at < 200, "sweep did not terminate"


def test_crash_during_sharded_round_shard_pool(tmp_path):
    swept = _crash_sweep(tmp_path / "perop", crash_shard=1,
                         crash_journal=False, group_commit=False)
    assert swept > 5                # the sweep actually crossed the batch
    # coalesced commit: far fewer fences on the shard pool, all swept
    gswept = _crash_sweep(tmp_path / "group", crash_shard=1,
                          crash_journal=False)
    assert 1 < gswept < swept


def test_crash_during_sharded_round_journal_pool(tmp_path):
    swept = _crash_sweep(tmp_path, crash_shard=None, crash_journal=True)
    assert swept > 1


def test_recover_is_idempotent(tmp_path):
    pools = [PMemPool(tmp_path / f"s{i}") for i in range(2)]
    backends = [DurableBackend(pool=p) for p in pools]
    journal = CrossShardJournal(PMemPool(tmp_path / "j"))
    # decide an op that was never applied anywhere: redo must apply it
    journal.decide("x0", [(0, 0, 0, 3), (1, 0, 0, 4)])
    sched = BatchScheduler(backends, ShardRouter(2, words_per_shard=4),
                           journal=journal)
    assert sched.recover() == 1
    assert sched.read(0) == 3 and sched.read(4) == 4
    assert sched.recover() == 0               # idempotent


# ---------------------------------------------------------------------------
# KVService: the structures front
# ---------------------------------------------------------------------------

def _spec(**kw):
    base = dict(n_ops=96, n_keys=24, read=0.3, update=0.3, insert=0.3,
                delete=0.1, batch=8, alpha=0.99, seed=5)
    base.update(kw)
    return WorkloadSpec(**base)


def test_kvservice_matches_flat_hashmap_reference():
    spec = _spec()
    ops = load_phase(spec) + compile_workload(spec)
    svc = KVService(4, structure="hashmap", n_buckets=2 * spec.n_keys,
                    round_cap=8)
    got = svc.apply(ops)
    ref_map = HashMap(KernelBackend(n_words=16 * spec.n_keys,
                                    use_kernel=False), 8 * spec.n_keys)
    want = ref_map.apply(ops)
    assert [r.status for r in got] == [r.status for r in want]
    assert svc.check_integrity() == ref_map.check_integrity()
    # client-side replay agrees too
    assert svc.items() == replay_effects(
        [(r.op, r.status) for r in got])


def test_kvservice_many_clients_interleaved():
    spec = _spec(n_ops=64)
    streams = client_streams(spec, 8)
    assert len(streams) == 8 and all(len(s) == 8 for s in streams)
    svc = KVService(4, structure="hashmap", n_buckets=64, round_cap=8)
    futs = []
    for client, stream in enumerate(streams):
        futs += [svc.submit(op, client=client) for op in stream]
    svc.drain()
    assert all(f.done for f in futs)
    svc.check_integrity()
    st = svc.stats
    assert st.completed == len(futs) == st.submitted
    assert st.p99_latency_rounds >= st.p50_latency_rounds >= 1
    assert 0 < st.occupancy <= 1
    assert st.steps < len(futs)               # batching actually batched


def test_kvservice_round_cap_bounds_occupancy():
    svc = KVService(1, structure="hashmap", n_buckets=64, round_cap=2)
    svc.apply([KVOp(INSERT, k, k) for k in range(1, 11)])
    s = svc.stats.shards[0]
    assert s.rounds >= 5 and s.overflows > 0
    assert svc.stats.occupancy <= 1.0


def test_kvservice_bztree_shards_split_and_gc():
    svc = KVService(2, structure="bztree", leaf_cap=2, root_cap=4,
                    n_regions=6, round_cap=4)
    res = svc.apply([KVOp(INSERT, k, k) for k in range(1, 13)])
    assert all(r.status == OK for r in res)
    before = svc.check_integrity()
    assert len(before) == 12
    assert sum(t.splits for t in svc.structs) >= 2
    freed = svc.gc_regions()
    assert freed >= 1                         # frozen originals reclaimed
    assert svc.check_integrity() == before


def test_kvservice_durable_crash_recover(tmp_path):
    spec = _spec(n_ops=48)
    svc = KVService(2, structure="hashmap", backend="durable",
                    n_buckets=48, durable_root=tmp_path)
    svc.apply(load_phase(spec) + compile_workload(spec))
    before = svc.check_integrity()
    svc2 = svc.crash()
    assert svc2.check_integrity() == before
    # and the recovered service keeps serving
    (r,) = svc2.apply([KVOp(INSERT, 1023, 9)])
    assert r.status in (OK, "exists")


def test_kvservice_custom_backend_factory():
    made = []

    def factory(n_words):
        b = KernelBackend(n_words=n_words, use_kernel=False)
        made.append(b)
        return b

    svc = KVService(3, structure="hashmap", backend=factory, n_buckets=8)
    assert len(made) == 3 and svc.backends == made
    register_backend("kernel_oracle_test",
                     lambda n_words=None, **kw: KernelBackend(
                         n_words=n_words, use_kernel=False))
    try:
        assert isinstance(make_backend("kernel_oracle_test", n_words=4),
                          KernelBackend)
    finally:
        from repro.pmwcas import BACKEND_FACTORIES
        BACKEND_FACTORIES.pop("kernel_oracle_test")


def test_partition_ops_matches_service_routing():
    from repro.structures import key_shard
    ops = compile_workload(_spec(n_ops=40))
    parts = partition_ops(ops, 4)
    router = ShardRouter(4, words_per_shard=8)
    assert router.shard_of_key(17) == key_shard(17, 4)   # one definition
    for s, part in enumerate(parts):
        assert all(router.shard_of_key(op.key) == s for op in part)
    assert sum(len(p) for p in parts) == len(ops)
    merged = interleave(client_streams(_spec(n_ops=32), 4))
    assert len(merged) == 32


def test_kvservice_scan_covers_every_shard():
    """Scans are keyspace-wide: the count must sum over all shard
    partitions, not just the shard the scan key hashes to."""
    keys = list(range(1, 25))
    for structure, kw in (("hashmap", dict(n_buckets=32)),
                          ("bztree", dict(leaf_cap=4, root_cap=8,
                                          n_regions=10))):
        svc = KVService(4, structure=structure, round_cap=8, **kw)
        svc.apply([KVOp(INSERT, k, k) for k in keys])
        (r,) = svc.apply([KVOp("scan", 1)])
        assert r.status == OK and r.value == len(keys), (structure, r)
        (r,) = svc.apply([KVOp("scan", 13)])
        assert r.value == len([k for k in keys if k >= 13])


def test_kvservice_region_exhaustion_is_counted():
    """The typed OutOfRegions reaches the service: exhaustion-FULL is
    distinguishable from root-FULL in the shard stats."""
    svc = KVService(1, structure="bztree", leaf_cap=2, root_cap=8,
                    n_regions=2, round_cap=4)
    res = svc.apply([KVOp(INSERT, k, k) for k in range(1, 9)])
    assert FULL in {r.status for r in res}
    assert svc.stats.shards[0].out_of_regions >= 1


def test_kvservice_exhaustion_counts_attempts_not_queue_delay():
    # queue delay never exhausts: a tiny round cap forces long queues,
    # yet every op completes OK because it never loses a round
    svc = KVService(1, structure="hashmap", n_buckets=64, round_cap=1,
                    max_op_rounds=1)
    res = svc.apply([KVOp(INSERT, k, k) for k in range(1, 13)])
    assert all(r.status == OK for r in res)
    # genuine retry churn does: with a zero attempt budget, the split
    # retry of a full-leaf insert exhausts instead of retrying
    tsvc = KVService(1, structure="bztree", leaf_cap=2, root_cap=4,
                     n_regions=4, max_op_rounds=0)
    res = tsvc.apply([KVOp(INSERT, k, k) for k in (1, 2, 3)])
    assert [r.status for r in res] == [OK, OK, "exhausted"]


def test_scheduler_drain_raises_instead_of_spinning():
    _, sched = _kernel_sched()
    sched.submit(MwCASOp([(0, 0, 1)]))
    with pytest.raises(ServiceError):
        sched.drain(max_steps=0)

"""The deprecation cycle promised in DESIGN.md Sec. 4 is over: user-side
code (benchmarks/, examples/, launch/) must import the PMwCAS world only
through the public surface (``repro`` / ``repro.pmwcas`` /
``repro.structures``), never the implementation layer (``repro.core``,
``repro.kernels.pmwcas_apply``, ``repro.checkpoint``).  The structures
package holds itself to an even stricter rule — it is the proof that the
unified API composes, so it may touch nothing below the public surface.
"""
import ast
import pathlib

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]

# the PMwCAS implementation layer of DESIGN.md Sec. 1 (adapters may wrap
# it; user-side code must not reach into it).  repro.kernels.flash_attention
# is a different subsystem and stays importable by its own tests.
IMPL_PREFIXES = ("repro.core", "repro.kernels.pmwcas_apply",
                 "repro.checkpoint")

USER_SIDE_DIRS = ("benchmarks", "examples", "src/repro/launch", "tests")


def repro_imports(path: pathlib.Path):
    """Absolute ``repro``-rooted module names imported by one file."""
    tree = ast.parse(path.read_text(), filename=str(path))
    found = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            found += [(a.name, node.lineno) for a in node.names
                      if a.name.split(".")[0] == "repro"]
        elif isinstance(node, ast.ImportFrom) and node.level == 0 \
                and node.module and node.module.split(".")[0] == "repro":
            found.append((node.module, node.lineno))
    return found


def files_under(*dirs):
    out = []
    for d in dirs:
        out += sorted((REPO / d).rglob("*.py"))
    assert out, f"no files found under {dirs} — layout changed?"
    return out


@pytest.mark.parametrize("path", files_under(*USER_SIDE_DIRS),
                         ids=lambda p: str(p.relative_to(REPO)))
def test_user_side_code_avoids_impl_layer(path):
    bad = [(mod, line) for mod, line in repro_imports(path)
           if mod.startswith(IMPL_PREFIXES)]
    assert not bad, (
        f"{path.relative_to(REPO)} imports the implementation layer "
        f"{bad}; use repro / repro.pmwcas / repro.structures "
        "(DESIGN.md Sec. 4 migration table)")


@pytest.mark.parametrize("path", files_under("src/repro/structures"),
                         ids=lambda p: str(p.relative_to(REPO)))
def test_structures_built_only_on_public_surface(path):
    allowed = {"repro", "repro.pmwcas"}
    bad = [(mod, line) for mod, line in repro_imports(path)
           if mod not in allowed]
    assert not bad, (
        f"{path.relative_to(REPO)} must build only on the public PMwCAS "
        f"surface, found {bad}")


@pytest.mark.parametrize("path", files_under("src/repro/service"),
                         ids=lambda p: str(p.relative_to(REPO)))
def test_service_built_only_on_public_surface(path):
    """The sharded service composes the layers below it ONLY through
    their public surfaces (the structures rule, one level up).
    ``repro.obs`` is the one sanctioned extra: instrumentation must be
    reachable from every layer, which is exactly why it imports nothing
    of repro itself (asserted below)."""
    allowed = {"repro", "repro.pmwcas", "repro.structures", "repro.obs"}
    bad = [(mod, line) for mod, line in repro_imports(path)
           if mod not in allowed]
    assert not bad, (
        f"{path.relative_to(REPO)} must build only on repro / "
        f"repro.pmwcas / repro.structures / repro.obs, found {bad}")


@pytest.mark.parametrize("path", files_under("src/repro/chaos"),
                         ids=lambda p: str(p.relative_to(REPO)))
def test_chaos_built_only_on_public_surface(path):
    """The chaos harness sits on top of everything and composes the
    layers below ONLY through their public surfaces."""
    allowed = {"repro", "repro.pmwcas", "repro.structures",
               "repro.service", "repro.obs"}
    bad = [(mod, line) for mod, line in repro_imports(path)
           if mod not in allowed]
    assert not bad, (
        f"{path.relative_to(REPO)} must build only on repro / "
        f"repro.pmwcas / repro.structures / repro.service / repro.obs, "
        f"found {bad}")


@pytest.mark.parametrize("path", files_under("src/repro/obs"),
                         ids=lambda p: str(p.relative_to(REPO)))
def test_obs_imports_nothing_above_pmwcas(path):
    """The observability layer sits at the BOTTOM of the import graph:
    anything (committer, service, chaos, benchmarks) may import it, so
    it must import nothing above ``repro.pmwcas`` — in practice nothing
    of repro at all (the stats adapters duck-type instead)."""
    allowed = {"repro", "repro.pmwcas"}
    bad = [(mod, line) for mod, line in repro_imports(path)
           if mod not in allowed]
    assert not bad, (
        f"{path.relative_to(REPO)} is the bottom layer; it may import "
        f"nothing above repro.pmwcas, found {bad}")


def test_public_surface_covers_the_migration_table():
    """Names the DESIGN.md Sec. 4 table routes through the public
    surface actually resolve there (the cycle can end safely)."""
    import repro
    for name in ("SimSession", "SimConfig", "run_sim", "CNT_CAS",
                 "TAG_DIRTY", "pmwcas_apply", "reserve_slots",
                 "Committer", "PMemPool", "data_rel", "HashMap",
                 "SortedNode", "FreeListAllocator", "zipf_probs",
                 "OutOfRegions", "KVService", "BatchScheduler",
                 "ShardRouter", "make_backend", "ScenarioDriver",
                 "chaos_sweep", "check_history",
                 "MetricsRegistry", "SpanTracer", "span",
                 "enable_tracing", "get_registry", "export_chrome_trace",
                 "fold_durability"):
        assert hasattr(repro, name), name
    import repro.pmwcas as pm
    for name in ("MwCASOp", "Backend", "run_differential", "zipf_probs",
                 "make_backend", "register_backend"):
        assert hasattr(pm, name), name

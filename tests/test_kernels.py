"""Pallas kernels vs pure-jnp oracles (interpret mode), swept over shapes,
dtypes and mask variants.  Property tests for the batched MwCAS
invariants run under hypothesis when it is installed (optional dep:
``pip install -e .[test]``) and fall back to a deterministic seed sweep
otherwise."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # optional dependency
    HAVE_HYPOTHESIS = False

from repro.kernels.flash_attention.kernel import flash_attention_flat
from repro.models.attention import _sdpa_ref
from repro.pmwcas import (pmwcas_apply_ref, pmwcas_success_pallas,
                          pmwcas_success_ref, reserve_slots,
                          sequential_oracle)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FA_CASES = [
    # (B, KV, G, Sq, Sk, hd, causal, window, cap, dtype)
    (1, 1, 1, 16, 16, 8, True, 0, 0.0, jnp.float32),
    (2, 2, 2, 32, 32, 16, True, 0, 0.0, jnp.float32),
    (1, 2, 4, 24, 40, 8, True, 0, 0.0, jnp.float32),   # gqa + ragged tiles
    (1, 1, 1, 16, 48, 8, False, 0, 0.0, jnp.float32),  # cross-attn style
    (2, 1, 2, 32, 32, 8, True, 9, 0.0, jnp.float32),   # sliding window
    (1, 2, 1, 32, 32, 8, True, 0, 30.0, jnp.float32),  # softcap (gemma2)
    (1, 1, 2, 16, 16, 8, True, 0, 0.0, jnp.bfloat16),  # bf16 inputs
    (1, 1, 1, 1, 40, 8, True, 0, 0.0, jnp.float32),    # decode: Sq=1
]


@pytest.mark.parametrize("case", FA_CASES)
def test_flash_kernel_matches_ref(case):
    B, KV, G, Sq, Sk, hd, causal, window, cap, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, KV, G, Sq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, Sk, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, Sk, hd), jnp.float32)
    qp = (jnp.arange(Sq) + (Sk - Sq if causal and Sq == 1 else 0))
    kp = jnp.arange(Sk)
    kw = dict(causal=causal, window=window, attn_cap=cap,
              scale=1.0 / np.sqrt(hd))
    ref = _sdpa_ref(q.astype(dtype), k.astype(dtype), v.astype(dtype),
                    qp, kp, **kw)
    got = flash_attention_flat(
        q.reshape(B * KV * G, Sq, hd).astype(dtype),
        k.reshape(B * KV, Sk, hd).astype(dtype),
        v.reshape(B * KV, Sk, hd).astype(dtype),
        qp, kp, g=G, tq=16, tk=16, interpret=True,
        **kw).reshape(B, KV, G, Sq, hd)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# pmwcas_apply
# ---------------------------------------------------------------------------

def _random_case(rng, W, B, K, pad_frac=0.1, val_range=4):
    words = rng.integers(0, val_range, W).astype(np.uint32)
    addr = np.stack([rng.choice(W, K, replace=False) for _ in range(B)])
    addr = np.sort(addr, axis=1).astype(np.int32)
    addr[rng.random((B, K)) < pad_frac] = -1
    exp = rng.integers(0, val_range, (B, K)).astype(np.uint32)
    des = (exp + 1).astype(np.uint32)
    return words, addr, exp, des


@pytest.mark.parametrize("W,B,K,tb", [
    (32, 8, 1, 4), (64, 32, 3, 8), (128, 64, 4, 16), (64, 17, 2, 8),
])
def test_pmwcas_kernel_matches_ref(W, B, K, tb):
    rng = np.random.default_rng(42 + W + B + K)
    words, addr, exp, des = _random_case(rng, W, B, K)
    cur = jnp.asarray(words)[jnp.maximum(jnp.asarray(addr), 0)]
    s_ref = np.asarray(pmwcas_success_ref(jnp.asarray(addr), cur,
                                          jnp.asarray(exp)))
    s_ker = np.asarray(pmwcas_success_pallas(jnp.asarray(addr), cur,
                                             jnp.asarray(exp), tb=tb))
    np.testing.assert_array_equal(s_ref, s_ker)


def _check_pmwcas_invariants(seed, B, K, W):
    """Conservative-batch invariants against the sequential oracle:
    1. every batch success also succeeds sequentially (containment),
    2. winners' writes match, losers leave words untouched,
    3. no address written twice."""
    rng = np.random.default_rng(seed)
    if K > W:
        K = W
    words, addr, exp, des = _random_case(rng, W, B, K)
    new, succ = pmwcas_apply_ref(jnp.asarray(words), jnp.asarray(addr),
                                 jnp.asarray(exp), jnp.asarray(des))
    new, succ = np.asarray(new), np.asarray(succ)
    _, s_seq = sequential_oracle(words, addr, exp, des)
    assert (~succ | s_seq).all()
    touched = {}
    for i in range(B):
        for k in range(K):
            a = addr[i, k]
            if a < 0:
                continue
            if succ[i]:
                assert a not in touched, "double write"
                touched[a] = des[i, k]
    for a in range(W):
        expect = touched.get(a, words[a])
        assert new[a] == expect


# Deterministic fallback sweep: always runs, hypothesis or not.
@pytest.mark.parametrize("seed,B,K,W", [
    (0, 1, 1, 16), (1, 40, 4, 16), (2, 17, 2, 64), (3, 32, 3, 256),
    (4, 8, 4, 16), (5, 25, 1, 64),
])
def test_pmwcas_invariants_deterministic(seed, B, K, W):
    _check_pmwcas_invariants(seed, B, K, W)


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2 ** 16), B=st.integers(1, 40),
           K=st.integers(1, 4), W=st.sampled_from([16, 64, 256]))
    def test_pmwcas_invariants(seed, B, K, W):
        _check_pmwcas_invariants(seed, B, K, W)
else:
    def test_pmwcas_invariants():
        pytest.importorskip("hypothesis")  # records skip: optional dep absent


# ---------------------------------------------------------------------------
# reserve_slots (serving-layer slot admission)
# ---------------------------------------------------------------------------

def _both_paths(free, reqs):
    """Run reserve_slots through the Pallas kernel AND the jnp oracle,
    assert they agree, return the (mask, granted) verdict."""
    new_k, g_k = reserve_slots(free, reqs, use_kernel=True)
    new_r, g_r = reserve_slots(free, reqs, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(g_k), np.asarray(g_r))
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    return np.asarray(new_k), np.asarray(g_k)


def test_reserve_slots_grants_disjoint():
    """Serving-layer use: concurrent requests get disjoint cache slots."""
    free = jnp.ones(64, jnp.uint32)
    rng = np.random.default_rng(7)
    reqs = jnp.asarray(
        np.stack([np.sort(rng.choice(64, 4, replace=False))
                  for _ in range(16)]), jnp.int32)
    new, granted = _both_paths(free, reqs)
    claimed = []
    for i in range(16):
        if granted[i]:
            claimed.extend(np.asarray(reqs)[i].tolist())
    assert len(claimed) == len(set(claimed))
    assert all(new[c] == 0 for c in claimed)
    # all other slots still free
    rest = set(range(64)) - set(claimed)
    assert all(new[list(rest)] == 1)


def test_reserve_slots_duplicate_ids_within_request():
    """A request listing the same slot twice claims it once and is still
    granted (the duplicate is a self-conflict, not a cross-request one)."""
    free = jnp.ones(8, jnp.uint32)
    reqs = jnp.asarray([[3, 3, 5, -1]], jnp.int32)
    new, granted = _both_paths(free, reqs)
    assert granted[0]
    assert new[3] == 0 and new[5] == 0
    assert new[[0, 1, 2, 4, 6, 7]].sum() == 6  # everything else untouched


def test_reserve_slots_all_padded_request():
    """An all-padded request (addr < 0 everywhere) is vacuously granted
    and claims nothing."""
    free = jnp.ones(8, jnp.uint32)
    reqs = jnp.asarray([[-1, -1, -1], [0, 1, -1]], jnp.int32)
    new, granted = _both_paths(free, reqs)
    assert granted[0] and granted[1]
    assert new[0] == 0 and new[1] == 0
    assert new[2:].sum() == 6


def test_reserve_slots_contention_lower_index_wins():
    """Overlapping requests linearize by batch index: the lower-index
    request wins every contested slot; later requests sharing any slot
    with a passing earlier request are denied atomically (no partial
    grants)."""
    free = jnp.ones(16, jnp.uint32)
    reqs = jnp.asarray([
        [0, 1, 2, 3],      # wins
        [3, 4, 5, 6],      # shares 3 with request 0 -> denied, grants none
        [7, 8, 9, 10],     # disjoint -> wins
        [4, 5, 11, 12],    # 4/5 were NOT claimed (request 1 denied) but
                           # request 1 passed its expected check, so the
                           # conservative one-shot verdict still denies
    ], jnp.int32)
    new, granted = _both_paths(free, reqs)
    assert granted.tolist() == [True, False, True, False]
    assert all(new[s] == 0 for s in [0, 1, 2, 3, 7, 8, 9, 10])
    # denied requests must not leave partial claims
    assert all(new[s] == 1 for s in [4, 5, 6, 11, 12, 13, 14, 15])


def test_reserve_slots_already_claimed_slot_fails():
    """Requests against a non-free slot fail their expected check."""
    free = jnp.ones(8, jnp.uint32).at[2].set(0)
    reqs = jnp.asarray([[1, 2, -1]], jnp.int32)
    new, granted = _both_paths(free, reqs)
    assert not granted[0]
    assert new[1] == 1          # untouched: all-or-nothing

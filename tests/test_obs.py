"""repro.obs — metrics registry, span tracer, exporters, adapters, and
the accounting contracts the rest of the stack now relies on:

- the committer keeps TWO ledgers of the same commits (its
  ``DurabilityStats`` and the registry counters) through one helper, so
  the two must agree to the exact integer;
- stats survive crash/recover MONOTONE (no zeroing, no double-count);
- ``KVService.reset_stats`` opens a fresh measurement window (registry
  zeroed in place) without cooling the executor's trace cache;
- the WAL recovery span decomposes into named child phases (the
  acceptance criterion benchmarks and traces both read).
"""
import json

import pytest

from repro.obs import (NULL_SPAN, Counter, Histogram, MetricsRegistry,
                       SpanTracer, chrome_trace, disable_tracing,
                       enable_tracing, export_jsonl, fold_durability,
                       fold_service, get_registry, get_tracer,
                       reset_metrics, span, span_tree,
                       validate_chrome_trace)
from repro.pmwcas import DurabilityStats, DurableBackend, MwCASOp
from repro.service import KVService
from repro.structures import KVOp


@pytest.fixture(autouse=True)
def _quiesce_obs():
    """Leave the process-global tracer/registry clean for other tests."""
    yield
    disable_tracing()
    get_tracer().clear()
    reset_metrics()


# -- registry ------------------------------------------------------------------

def test_registry_get_or_create_and_label_series():
    reg = MetricsRegistry()
    a = reg.counter("flushes", component="committer")
    b = reg.counter("flushes", component="committer")
    assert a is b                       # same (name, labels) -> same object
    c = reg.counter("flushes", component="scheduler")
    assert c is not a                   # labels distinguish series
    a.inc(3)
    c.inc()
    assert reg.value("flushes", component="committer") == 3
    assert reg.total("flushes") == 4    # across every label combination
    assert reg.value("never_touched") == 0   # absent -> 0, not KeyError


def test_registry_reset_zeroes_in_place():
    reg = MetricsRegistry()
    held = reg.counter("x").inc(7)
    g = reg.gauge("y").set(1.5)
    h = reg.histogram("z").record(10.0)
    reg.reset()
    # the objects callers hold onto survive and read zero
    assert held is reg.counter("x") and held.value == 0
    assert g.value == 0.0
    assert h.count == 0 and h.samples == []


def test_histogram_percentiles_and_bounded_window():
    h = Histogram("lat", window=64)
    for us in range(1, 101):
        h.record(float(us))
    assert len(h.samples) == 64         # window bounds memory...
    assert h.count == 100               # ...lifetime count does not
    assert h.total_us == sum(range(1, 101))
    assert h.max_us == 100.0
    # percentiles are over the WINDOW (recent traffic): samples 37..100
    assert 60.0 <= h.p50_us <= 75.0
    assert h.p99_us >= 99.0
    assert h.summary()["count"] == 100


def test_counter_allows_corrective_negative_deltas():
    c = Counter("flushes_saved")
    c.inc(5).inc(-2)
    assert c.value == 3


# -- tracer --------------------------------------------------------------------

def test_disabled_tracer_is_the_null_singleton():
    t = SpanTracer()
    sp = t.span("anything", k=1)
    assert sp is NULL_SPAN
    with sp as s:
        s.set(ignored=True)             # no-op, no error
    assert len(t) == 0


def test_enabled_spans_record_nesting_as_parent_args():
    t = SpanTracer()
    t.enable()
    with t.span("outer", a=1):
        with t.span("inner") as sp:
            sp.set(found=3)
    events = t.events()
    assert [e["name"] for e in events] == ["inner", "outer"]  # exit order
    inner, outer = events
    assert inner["ph"] == outer["ph"] == "X"
    assert inner["args"]["parent"] == "outer"
    assert inner["args"]["found"] == 3
    assert "parent" not in outer["args"] and outer["args"]["a"] == 1
    assert inner["ts"] >= outer["ts"] >= 0
    assert span_tree(events) == {"outer": ["inner"]}


def test_ring_buffer_drops_oldest_and_counts():
    t = SpanTracer(capacity=4)
    t.enable()
    for i in range(6):
        with t.span(f"s{i}"):
            pass
    assert len(t) == 4
    assert t.dropped == 2
    assert [e["name"] for e in t.events()] == ["s2", "s3", "s4", "s5"]
    t.clear()
    assert len(t) == 0 and t.dropped == 0


def test_instant_events_record_when_enabled_only():
    t = SpanTracer()
    t.instant("off")
    assert len(t) == 0
    t.enable()
    t.instant("on", shard=2)
    (ev,) = t.events()
    assert ev["ph"] == "i" and ev["args"] == {"shard": 2}


# -- exporters -----------------------------------------------------------------

def _traced():
    t = SpanTracer()
    t.enable()
    with t.span("parent"):
        with t.span("child", n=1):
            pass
        t.instant("tick")
    return t


def test_chrome_trace_validates_and_survives_json_roundtrip(tmp_path):
    t = _traced()
    obj = json.loads(json.dumps(chrome_trace(t)))
    validate_chrome_trace(obj)
    assert obj["traceEvents"][0]["ph"] == "M"   # process_name metadata
    assert obj["otherData"]["dropped_events"] == 0
    names = [e["name"] for e in obj["traceEvents"]]
    assert {"parent", "child", "tick"} <= set(names)


def test_export_jsonl_one_event_per_line(tmp_path):
    t = _traced()
    path = export_jsonl(tmp_path / "events.jsonl", t)
    lines = path.read_text().splitlines()
    assert len(lines) == len(t)
    assert all(isinstance(json.loads(ln), dict) for ln in lines)


@pytest.mark.parametrize("bad", [
    "not a dict",
    {},                                              # no traceEvents
    {"traceEvents": [{"ph": "X", "ts": 0, "dur": 1}]},   # nameless
    {"traceEvents": [{"name": "x", "ph": "Q", "ts": 0}]},  # unknown phase
    {"traceEvents": [{"name": "x", "ph": "X", "ts": -1, "dur": 1}]},
    {"traceEvents": [{"name": "x", "ph": "X", "ts": 0}]},  # X without dur
    {"traceEvents": [{"name": "x", "ph": "i", "ts": 0, "pid": "one"}]},
])
def test_validator_rejects_malformed_traces(bad):
    with pytest.raises(ValueError):
        validate_chrome_trace(bad)


# -- adapters ------------------------------------------------------------------

def test_fold_durability_is_idempotent():
    reg = MetricsRegistry()
    stats = DurabilityStats(flushes_issued=10, flushes_saved=4, fences=3,
                            round_commits=3, ops_committed=9)
    fold_durability(stats, reg, backend="durable")
    fold_durability(stats, reg, backend="durable")   # fold twice: same
    assert reg.value("durability.flushes_issued", backend="durable") == 10
    assert reg.value("durability.flushes_per_commit",
                     backend="durable") == stats.flushes_per_commit
    assert len(reg.series("durability.flushes_issued")) == 1


def test_fold_service_covers_latency_and_shards():
    from repro.service import fresh_stats
    reg = MetricsRegistry()
    stats = fresh_stats(2, round_cap=4)
    stats.record_completion(3, "ok", latency_us=120.0)
    stats.record_completion(5, "ok", latency_us=480.0)
    fold_service(stats, reg)
    assert reg.value("service.completed") == 2
    assert reg.value("service.p99_latency_us") > 0
    assert reg.value("service.shard.rounds", shard=0) == 0
    assert reg.value("service.by_status", status="ok") == 2


# -- the committer's two ledgers ----------------------------------------------

def _mutate(backend, rounds=3, width=4, start=0):
    for r in range(start, start + rounds):
        ops = [MwCASOp([(2 * i, r, r + 1), (2 * i + 1, r, r + 1)])
               for i in range(width)]
        assert all(res.success for res in backend.execute(ops))


def test_committer_stats_and_registry_agree_exactly(tmp_path):
    reset_metrics()
    b = DurableBackend(root=tmp_path)
    _mutate(b)
    st = b.committer.stats
    assert st.flushes_issued > 0 and st.ops_committed > 0
    reg = get_registry()
    for field in ("flushes_issued", "flushes_saved", "fences",
                  "round_commits", "op_commits", "ops_committed"):
        assert reg.value(field, component="committer") == \
            getattr(st, field), field


def test_recovery_span_decomposes_and_times_itself(tmp_path):
    b = DurableBackend(root=tmp_path)
    _mutate(b)
    reset_metrics()
    enable_tracing().clear()
    try:
        b2 = b.crash()
    finally:
        disable_tracing()
    tree = span_tree(get_tracer().events())
    assert "wal.recover" in tree.get("backend.crash_recover", [])
    # the acceptance bar: recovery decomposes into >= 3 named phases
    assert len(tree["wal.recover"]) >= 3, tree["wal.recover"]
    hist = get_registry().histogram("recover_us", component="committer")
    assert hist.count >= 1 and hist.total_us > 0
    assert b2.read(0) == b.read(0)


def test_durability_stats_monotone_across_backend_crash(tmp_path):
    b = DurableBackend(root=tmp_path)
    _mutate(b)
    before = b.committer.stats
    snap = (before.flushes_issued, before.fences, before.ops_committed)
    b2 = b.crash()
    after = b2.committer.stats
    assert after is before             # the SAME ledger, carried through
    assert (after.flushes_issued, after.fences,
            after.ops_committed) == snap   # recovery bills nothing twice
    _mutate(b2, rounds=1, start=3)     # words hold 3 after the warm-up
    assert after.ops_committed > snap[2]   # and it keeps counting


# -- service-level lifecycle (satellites 1-3) ---------------------------------

def _drive(svc, n=24, key0=1):
    for i in range(n):
        svc.submit(KVOp("insert", key0 + i, i + 1), client=i % 4)
    svc.drain()


def test_service_wall_clock_percentiles(tmp_path):
    svc = KVService(2, structure="hashmap", n_buckets=64)
    _drive(svc)
    row = svc.stats.as_row()
    assert row["p99_latency_us"] >= row["p50_latency_us"] > 0
    assert svc.stats.latency_us.count == svc.stats.completed


def test_service_stats_monotone_across_crash(tmp_path):
    svc = KVService(2, structure="hashmap", backend="durable",
                    n_buckets=64, durable_root=tmp_path)
    _drive(svc)
    s = svc.stats
    steps0, sub0, done0 = s.steps, s.submitted, s.completed
    d0 = svc.durability_stats()
    svc2 = svc.crash()
    assert svc2.stats is s             # the window survives the crash
    assert (s.steps, s.submitted, s.completed) == (steps0, sub0, done0)
    d1 = svc2.durability_stats()
    for field in ("flushes_issued", "fences", "ops_committed"):
        assert getattr(d1, field) >= getattr(d0, field), field
    _drive(svc2, n=8, key0=1001)
    assert s.completed > done0 and s.steps > steps0


def test_reset_stats_zeroes_registry_window(tmp_path):
    svc = KVService(2, structure="hashmap", backend="durable",
                    n_buckets=64, durable_root=tmp_path)
    _drive(svc)
    reg = get_registry()
    assert reg.value("flushes_issued", component="committer") > 0
    svc.reset_stats()
    assert reg.value("flushes_issued", component="committer") == 0
    assert svc.stats.completed == 0
    d_mid = svc.durability_stats().flushes_issued   # cumulative ledger
    _drive(svc, n=8, key0=2001)        # the next window counts afresh
    window = reg.value("flushes_issued", component="committer")
    assert window > 0
    assert window == svc.durability_stats().flushes_issued - d_mid


def test_reset_stats_keeps_trace_cache_warm():
    svc = KVService(2, structure="hashmap", n_buckets=64)
    _drive(svc)                        # warm-up: traces the shapes
    assert svc.stats.dispatch is not None
    svc.reset_stats()
    _drive(svc, key0=101)              # fresh keys, same dispatch shapes
    assert svc.stats.dispatch is not None
    assert svc.stats.dispatch.traces == 0, \
        "reset_stats must not cool the executor's trace cache"
    assert svc.stats.dispatch.hits > 0

"""Fault-tolerance demo: crash a training run mid-stream, restart, and
verify the run continues EXACTLY where the last atomic checkpoint left it
(params + optimizer + data position restored together — never torn).

Run:  PYTHONPATH=src python examples/crash_recovery.py
"""
import shutil

from repro.configs.base import LayerSpec, ModelConfig
from repro.data.synthetic import DataConfig
from repro.models import build_model
from repro.optim import adamw
from repro.runtime import Trainer, TrainerConfig

CKPT = "/tmp/repro_crash_demo"
shutil.rmtree(CKPT, ignore_errors=True)

cfg = ModelConfig(name="crash-demo", family="dense", n_layers=2,
                  d_model=64, n_heads=4, n_kv_heads=4, d_ff=256, vocab=512,
                  unit=(LayerSpec(kind="attn", ffn="dense"),))


def make_trainer():
    return Trainer(
        build_model(cfg),
        adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                          weight_decay=0.0),
        DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4),
        TrainerConfig(total_steps=60, ckpt_every=10, ckpt_dir=CKPT),
    )


print("=== phase 1: train, then crash at step 34 ===")
t1 = make_trainer()
try:
    t1.run(crash_at_step=34)
    raise SystemExit("crash did not fire?")
except RuntimeError as e:
    print(f"  {e} (last committed checkpoint: step 30)")

print("=== phase 2: restart — resumes from the atomic checkpoint ===")
t2 = make_trainer()
params, opt, stream, start = t2.restore_or_init()
print(f"  restored training state at step {start} "
      f"(data stream position {stream.step})")
assert start == 30, start
assert stream.step == stream.state()["step"]

params, opt, losses = t2.run()
print(f"  completed remaining {len(losses)} steps; final loss "
      f"{losses[-1]:.4f}")

print("=== phase 3: reference run without crash — same data order ===")
shutil.rmtree(CKPT, ignore_errors=True)
t3 = make_trainer()
_, _, ref_losses = t3.run()
print(f"  reference final loss {ref_losses[-1]:.4f}")
diff = abs(ref_losses[-1] - losses[-1])
print(f"  |crash-run - reference| = {diff:.6f} (identical data order, "
      f"same seeds => tiny drift from re-randomized init only at step 0)")
print("crash recovery demo OK")

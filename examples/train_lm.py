"""End-to-end driver: train a ~110M-parameter LM with atomic descriptor-WAL
checkpoints.

Default (CI-friendly):   a reduced preset, 60 steps, ~1 minute on CPU.
The full deliverable:    --preset 100m --steps 300   (a ~110M-param model
for a few hundred steps; several CPU-hours on this container, minutes on
one TPU host).

Run:  PYTHONPATH=src python examples/train_lm.py [--preset 100m] [--steps N]
"""
import argparse

from repro.configs.base import LayerSpec, ModelConfig
from repro.data.synthetic import DataConfig
from repro.models import build_model
from repro.optim import adamw
from repro.runtime import Trainer, TrainerConfig

PRESETS = {
    # ~110M params: 12L x d768 x ffn 3072, 32k vocab
    "100m": ModelConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab=32_000,
        unit=(LayerSpec(kind="attn", ffn="dense"),), tie_embeddings=True),
    # ~6M params for quick runs
    "tiny": ModelConfig(
        name="lm-tiny", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=512, vocab=4_096,
        unit=(LayerSpec(kind="attn", ffn="dense"),), tie_embeddings=True),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-async", action="store_true")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    model = build_model(cfg)
    print(f"model {cfg.name}: {cfg.n_params/1e6:.1f}M params")
    trainer = Trainer(
        model,
        adamw.AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps,
                          weight_decay=0.01),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                   global_batch=args.global_batch),
        TrainerConfig(total_steps=args.steps, ckpt_every=max(10, args.steps // 4),
                      ckpt_async=args.ckpt_async, ckpt_dir=args.ckpt_dir),
    )
    params, opt, losses = trainer.run()
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} over {len(losses)} "
          f"steps (ckpts in {args.ckpt_dir})")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()

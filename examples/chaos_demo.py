"""Chaos harness demo: statechart-driven clients and faults against a
durable KV service, with the linearizability checker as the referee.

1. One scenario, narrated: six statechart clients (Zipf draws whose hot
   keys drift) run against a 2-shard durable service while a fault
   machine arms crash traps a few persists ahead — the service crashes
   mid-wave, recovers every shard from its WAL in place, and the run
   keeps going.  The checker then replays the completed history against
   a sequential oracle: verdicts observed before a crash must be
   explainable, ops in flight AT the crash may have landed or not
   (indeterminate), and the recovered state must be reachable from the
   in-flight set.
2. The determinism claim, demonstrated: the same scenario seed re-run
   produces a byte-identical event trace — crashes included.
3. The full sweep: every named family (hot-key storm, crash-mid-scan,
   straggler, drifting skew, sim-native) runs and every history checks.

Run:  PYTHONPATH=src python examples/chaos_demo.py
"""
import tempfile

from repro.chaos import ScenarioDriver, chaos_sweep, hot_key_storm


def main():
    print("=== 1. one scenario, close up ===========================")
    sc = hot_key_storm(seed=2, waves=50)
    with tempfile.TemporaryDirectory() as tmp:
        rep = ScenarioDriver(sc, durable_root=tmp).run()
    print(rep.summary())
    print(f"  {rep.waves_run} waves, {rep.crashes} crash/recover cycles, "
          f"{rep.check.indeterminate} in-flight verdicts lost to crashes")
    print(f"  WAL after run: {rep.wal_records} records "
          f"({rep.wal_pruned} pruned by the wave cadence)")
    print(f"  final live keys: {sorted(rep.final_items)}")
    assert rep.check.ok and rep.crashes >= 1

    print()
    print("=== 2. same seed, same chaos ============================")
    with tempfile.TemporaryDirectory() as tmp:
        rep2 = ScenarioDriver(sc, durable_root=tmp).run()
    same = rep2.trace_lines == rep.trace_lines
    print(f"  re-run trace identical: {same} "
          f"({len(rep.trace_lines)} trace lines)")
    assert same and rep2.final_items == rep.final_items

    print()
    print("=== 3. the full family sweep ============================")
    for r in chaos_sweep(seed=0, waves=40):
        print(f"  {r.summary()}")
        assert r.check.ok
    print("every completed history is linearizable")


if __name__ == "__main__":
    main()

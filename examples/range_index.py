"""A persistent lock-free RANGE INDEX in ~60 lines of driver code — the
multi-node payoff of the paper's PMwCAS (DESIGN.md Sec. 7):

1. A two-level BzTree fills until leaves overflow; every split is the
   one-wide-MwCAS half materialization followed by a 2-word parent
   install (pointer swing + separator count bump).
2. The scan-heavy YCSB-E mix — the workload range indexes exist for —
   runs against the tree on the batched kernel backend.
3. The same tree on the durable descriptor-WAL backend, then a crash:
   a fresh index attaches to the recovered words with zero lost commits
   and no torn node, and the WAL is pruned of spent descriptors.
4. The three-substrate differential: kernel and durable trees agree
   op-by-op and every CAS round is shadow-verified on the simulator.

Run:  PYTHONPATH=src python examples/range_index.py
"""
import dataclasses

from repro.pmwcas import DurableBackend, KernelBackend
from repro.structures import (BzTreeIndex, INSERT, KVOp, SCAN, YCSB_E,
                              compile_workload, load_phase,
                              run_struct_differential, run_workload)

SHAPE = dict(leaf_cap=4, root_cap=8, n_regions=10)
SPEC = dataclasses.replace(YCSB_E, n_ops=64, n_keys=24, batch=8,
                           alpha=0.9, seed=42)

print("=== 1. grow a two-level BzTree through leaf splits ===")
n_words = BzTreeIndex.words_needed(**SHAPE)
tree = BzTreeIndex(KernelBackend(n_words=n_words, use_kernel=False), **SHAPE)
tree.apply([KVOp(INSERT, k, 100 + k) for k in range(1, 17)])
print(f"  16 inserts -> {tree.splits} splits, "
      f"{len(tree.leaf_bases())} leaves, root holds {tree.root_count()} "
      f"separators")
tree.check_integrity()

print("\n=== 2. YCSB-E (scan-heavy) on the range index ===")
stats = run_workload(tree, SPEC)
(scan,) = tree.apply([KVOp(SCAN, 8)])
print(f"  {stats.n_ops} logical ops -> {stats.mwcas_submitted} MwCAS "
      f"({stats.rounds} rounds); outcomes "
      f"{dict(sorted(stats.by_status.items()))}")
print(f"  scan(key >= 8) counts {scan.value} live keys across "
      f"{len(tree.leaf_bases())} leaves")

print("\n=== 3. the same tree on the durable backend + crash ===")
db = DurableBackend()
dtree = BzTreeIndex(db, **SHAPE)
dtree.apply(load_phase(SPEC))
before = dtree.check_integrity()
pruned = db.prune_completed()                    # WAL hygiene
recovered = BzTreeIndex(db.crash(), **SHAPE)     # crash + attach
after = recovered.check_integrity()
assert after == before, "lost or torn state across the crash!"
print(f"  {len(before)} live keys before crash == {len(after)} after "
      f"recovery; {pruned} spent WAL descriptors pruned; no torn node")

print("\n=== 4. three-substrate differential on a splitting workload ===")
ops = load_phase(SPEC) + compile_workload(
    dataclasses.replace(SPEC, n_ops=32, scan=0.25, insert=0.45, read=0.2,
                        update=0.1))
rep = run_struct_differential(ops, structure="bztree", **SHAPE)
print("  " + rep.summary().replace("\n", "\n  "))
assert rep.agree and rep.sim_rounds_checked >= 1
print("range_index OK")

"""A persistent lock-free KV store in ~60 lines of driver code — the
paper's "productive uses of PMwCAS" claim, running on the structures
layer:

1. A YCSB-style workload (Zipfian keys, mixed ops) on the lock-free
   hash map over the batched kernel backend; every mutation is one
   2-word PMwCAS.
2. The same logical workload on the durable descriptor-WAL backend —
   then a crash: recovery reattaches the map with zero lost commits
   and zero torn bucket pairs.
3. The three-substrate differential: kernel and durable agree op-by-op,
   and every CAS round is shadow-verified on the cycle-accurate
   simulator.
4. A BzTree-style node fills up, splits with ONE wide PMwCAS, and a
   parent pointer swings atomically — the index building block.

Run:  PYTHONPATH=src python examples/kv_store.py
"""
import dataclasses

from repro.pmwcas import DurableBackend, KernelBackend
from repro.structures import (HashMap, SortedNode, YCSB_A, NODE_FULL,
                              compile_workload, load_phase,
                              run_struct_differential, run_workload,
                              swap_pointer, read_pointer)

SPEC = dataclasses.replace(YCSB_A, n_ops=96, n_keys=24, batch=8,
                           alpha=0.99, seed=42)

print("=== 1. YCSB-A on the lock-free hash map (kernel backend) ===")
kmap = HashMap(KernelBackend(n_words=4 * SPEC.n_keys, use_kernel=False),
               2 * SPEC.n_keys)
kmap.apply(load_phase(SPEC))
stats = run_workload(kmap, SPEC)
print(f"  {stats.n_ops} logical ops -> {stats.mwcas_submitted} MwCAS "
      f"({stats.rounds} rounds, {stats.retries_per_op:.3f} retries/op)")
print(f"  outcomes: {dict(sorted(stats.by_status.items()))}")
kmap.check_integrity()

print("\n=== 2. same workload, durable backend + crash ===")
db = DurableBackend()
dmap = HashMap(db, 2 * SPEC.n_keys)
dmap.apply(load_phase(SPEC))
run_workload(dmap, SPEC)
before = dmap.check_integrity()
recovered = HashMap(db.crash(), 2 * SPEC.n_keys)   # crash + reattach
after = recovered.check_integrity()
assert after == before, "lost or torn state across the crash!"
print(f"  {len(before)} live keys before crash == {len(after)} after "
      f"recovery; no torn bucket pairs")

print("\n=== 3. three-substrate differential on a conflict workload ===")
ops = compile_workload(dataclasses.replace(
    SPEC, n_ops=32, n_keys=8, read=0.2, update=0.2, insert=0.5, delete=0.1))
rep = run_struct_differential(ops, n_buckets=8)
print("  " + rep.summary().replace("\n", "\n  "))
assert rep.agree and rep.sim_rounds_checked >= 1

print("\n=== 4. BzTree node: fill, split (one wide PMwCAS), install ===")
kb = KernelBackend(n_words=64, use_kernel=False)
ROOT_PTR = 40
node = SortedNode(kb, base=0, capacity=8)
node.insert_batch([50, 20, 80, 10, 60, 30, 70, 40])
assert node.insert(90) == NODE_FULL
left, right, sep = node.split(10, 20)
swap_pointer(kb, ROOT_PTR, 0, left.base)
print(f"  split {node.keys()} -> {left.keys()} | {right.keys()} "
      f"(separator {sep})")
assert node.frozen and node.keys() == sorted(left.keys() + right.keys())
print(f"  root pointer now -> node@{read_pointer(kb, ROOT_PTR)}; frozen "
      f"original still intact: {node.keys()}")
print("kv_store OK")

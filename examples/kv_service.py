"""A sharded, batched KV service in ~70 lines of driver code — the
many-client layer on top of the lock-free structures:

1. Eight clients fire a Zipf-skewed mutation-heavy workload at a
   4-shard service; per-shard conflict-free rounds execute in one wave
   per step (kernel shards in ONE stacked vmapped dispatch), duplicate-
   target ops are deferred instead of executed-to-lose, and per-op
   latency comes back in rounds (p50/p99).
2. The same workload on a single shard: aggregate round throughput
   drops — sharding is the scaling lever (the service benchmark gates
   on this).
3. A durable service: every commit is a real descriptor-WAL persist;
   the service crashes, every shard recovers from its own WAL, nothing
   is lost or torn.
4. The raw scheduler: a cross-shard MwCAS runs in a serialized global
   round under a decision journal, so even a crash between shard
   applications cannot half-apply it.

Run:  PYTHONPATH=src python examples/kv_service.py
"""
import dataclasses
import pathlib
import tempfile

from repro import PMemPool
from repro.pmwcas import DurableBackend, MwCASOp
from repro.service import (BatchScheduler, CrossShardJournal, KVService,
                           ShardRouter)
from repro.structures import (WorkloadSpec, client_streams, load_phase)

SPEC = WorkloadSpec(n_ops=160, n_keys=32, read=0.1, update=0.55,
                    insert=0.25, delete=0.1, alpha=0.9, seed=7)
N_CLIENTS = 8


def drive(svc):
    """Load the key universe, then submit 8 interleaved client streams."""
    svc.apply(load_phase(SPEC, fraction=1.0))
    svc.reset_stats()
    streams = client_streams(SPEC, N_CLIENTS)
    for i in range(max(len(s) for s in streams)):
        for client, stream in enumerate(streams):
            if i < len(stream):
                svc.submit(stream[i], client=client)
    svc.drain()
    svc.check_integrity()
    return svc.stats


print("=== 1. 8 clients on a 4-shard service (stacked kernel rounds) ===")
svc4 = KVService(4, structure="hashmap", n_buckets=2 * SPEC.n_keys,
                 round_cap=4)
st4 = drive(svc4)
print("  " + st4.summary().replace("\n", "\n  "))
print(f"  executor: {type(svc4.executor).__name__} "
      f"({svc4.executor.stacked_dispatches} stacked dispatches)")

print("\n=== 2. same traffic, one shard: round throughput drops ===")
svc1 = KVService(1, structure="hashmap", n_buckets=8 * SPEC.n_keys,
                 round_cap=4)
st1 = drive(svc1)
print(f"  S=4: {st4.ops_per_step:.1f} ops/round-wave   "
      f"S=1: {st1.ops_per_step:.1f} ops/round-wave")
assert st4.ops_per_step > st1.ops_per_step, "sharding must scale"
assert svc1.items() == svc4.items(), "sharding must not change results"

print("\n=== 3. durable service: crash every shard, recover via WALs ===")
with tempfile.TemporaryDirectory() as tmp:
    dsvc = KVService(2, structure="hashmap", backend="durable",
                     n_buckets=2 * SPEC.n_keys, durable_root=tmp,
                     round_cap=4)
    small = dataclasses.replace(SPEC, n_ops=48)
    dsvc.apply(load_phase(small) + sum(client_streams(small, 4), []))
    before = dsvc.check_integrity()
    recovered = dsvc.crash()                      # drop caches, replay WALs
    after = recovered.check_integrity()
    assert after == before, "lost or torn state across the crash!"
    print(f"  {len(before)} live keys before crash == {len(after)} after; "
          f"no shard torn")

print("\n=== 4. cross-shard MwCAS: serialized + journaled ===")
with tempfile.TemporaryDirectory() as tmp:
    root = pathlib.Path(tmp)
    shards = [DurableBackend(root / f"s{i}") for i in range(2)]
    sched = BatchScheduler(shards, ShardRouter(2, words_per_shard=8),
                           journal=CrossShardJournal(PMemPool(root / "j")))
    f_local = sched.submit(MwCASOp([(0, 0, 1)]))          # shard 0
    f_cross = sched.submit(MwCASOp([(1, 0, 2), (9, 0, 3)]))  # spans 0+1
    sched.drain()
    assert f_local.success and f_cross.success
    assert (sched.read(1), sched.read(9)) == (2, 3)
    print(f"  local + cross committed; {sched.stats.cross_rounds} global "
          f"round, journal holds {len(sched.journal)} decision record(s)")
print("kv_service OK")

"""Quickstart: the PMwCAS core in five minutes.

1. Run the four algorithms in the many-core simulator; compare the exact
   CAS/flush counts (the paper's Sec. 2.1 claims).
2. Crash the simulation mid-flight and recover from the persisted
   descriptors (the descriptor-as-WAL insight of Sec. 4).
3. The paper's Fig. 1 scenario: atomically swap a linked-list payload
   pointer AND a thread-local region pointer with one 2-word PMwCAS, so a
   crash can never leak or double-free the payload.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (ALG_ORIGINAL, ALG_OURS, ALG_OURS_DF, ALG_PCAS,
                        SimConfig, check_crash_consistency, run_sim,
                        run_until)
from repro.core.model import CNT_CAS, CNT_FLUSH

print("=== 1. instruction counts per successful 3-word PMwCAS ===")
for alg in (ALG_OURS, ALG_OURS_DF, ALG_ORIGINAL):
    cfg = SimConfig(algorithm=alg, n_threads=1, n_words=256, k=3,
                    n_steps=3000, max_ops=64)
    r = run_sim(cfg)
    print(f"  {alg:10s} CAS-class/op = {r.per_op(CNT_CAS):5.2f}   "
          f"flush/op = {r.per_op(CNT_FLUSH):5.2f}")
print("  (paper: ours 2k=6 CAS, original 4k=12 CAS; dirty flags cost +k "
      "flushes)")

print("\n=== 2. crash anywhere, recover from descriptors ===")
cfg = SimConfig(algorithm=ALG_OURS, n_threads=4, n_words=64, k=3,
                n_steps=1000, max_ops=32, alpha=1.0)
for crash_step in (137, 423, 881):
    r = run_until(cfg, crash_step)
    rec, hist = check_crash_consistency(cfg, r.state)
    print(f"  crash@{crash_step}: recovered; committed increments = "
          f"{int(hist.sum())} — invariant holds")

print("\n=== 3. Fig. 1: atomic payload swap via 2-word PMwCAS ===")
# word 0: node.payload_ptr, word 1: thread_local.region_ptr
# swap them atomically: after ANY crash, exactly one of them owns each
# payload — the recovery procedure can always free the right one.
from repro.kernels.pmwcas_apply import ref as mw

words = np.asarray([10, 20], np.uint32)     # payload ids
addr = np.asarray([[0, 1]], np.int32)
exp = np.asarray([[10, 20]], np.uint32)
des = np.asarray([[20, 10]], np.uint32)     # swap!
new, ok = mw.pmwcas_apply(words, addr, exp, des)
print(f"  before: node->10, local->20 | after: node->{int(new[0])}, "
      f"local->{int(new[1])} | atomic={bool(ok[0])}")
assert bool(ok[0]) and int(new[0]) == 20 and int(new[1]) == 10
print("quickstart OK")

"""Quickstart: the PMwCAS core in five minutes, through the unified
``repro.pmwcas`` API.

1. Run the four algorithm strategies in the many-core simulator via the
   fluent SimSession; compare the exact CAS/flush counts against the
   strategies' analytical claims (the paper's Sec. 2.1).
2. Crash the simulation mid-flight and recover from the persisted
   descriptors (the descriptor-as-WAL insight of Sec. 4).
3. The paper's Fig. 1 scenario: atomically swap a linked-list payload
   pointer AND a thread-local region pointer with one 2-word MwCASOp on
   the kernel backend, so a crash can never leak or double-free the
   payload.
4. The same op batch through sim, kernel AND durable backends — one
   operation model, three substrates, identical verdicts.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.pmwcas import (CNT_CAS, CNT_FLUSH, KernelBackend, MwCASOp,
                          ORIGINAL, OURS, OURS_DF, SimSession,
                          increment_batch, run_differential)

print("=== 1. instruction counts per successful 3-word PMwCAS ===")
for alg in (OURS, OURS_DF, ORIGINAL):
    r = (SimSession().with_algorithm(alg)
         .with_threads(1).with_words(256).with_k(3)
         .with_steps(3000).with_max_ops(64)
         .run())
    # the engine counts the original algorithm's status-word CAS, which
    # the paper's 4k figure (and cas_per_op) excludes
    pred = alg.cas_per_op(3) + (1 if alg is ORIGINAL else 0)
    note = " incl. status CAS" if alg is ORIGINAL else ""
    print(f"  {alg.name:10s} CAS-class/op = {r.per_op(CNT_CAS):5.2f}   "
          f"flush/op = {r.per_op(CNT_FLUSH):5.2f}   "
          f"(strategy predicts {pred} CAS{note})")
print("  (paper: ours 2k=6 CAS, original 4k=12 CAS; dirty flags cost +k "
      "flushes)")

print("\n=== 2. crash anywhere, recover from descriptors ===")
crashable = (SimSession().with_algorithm(OURS)
             .with_threads(4).with_words(64).with_k(3)
             .with_steps(1000).with_max_ops(32).with_skew(1.0))
for crash_step in (137, 423, 881):
    rec, hist = crashable.crash_at(crash_step)
    print(f"  crash@{crash_step}: recovered; committed increments = "
          f"{int(hist.sum())} — invariant holds")

print("\n=== 3. Fig. 1: atomic payload swap via 2-word PMwCAS ===")
# word 0: node.payload_ptr, word 1: thread_local.region_ptr
# swap them atomically: after ANY crash, exactly one of them owns each
# payload — the recovery procedure can always free the right one.
kb = KernelBackend(values=[10, 20])         # payload ids
swap = MwCASOp([(0, 10, 20), (1, 20, 10)])  # swap!
(res,) = kb.execute([swap])
print(f"  before: node->10, local->20 | after: node->{kb.read(0)}, "
      f"local->{kb.read(1)} | atomic={res.success}")
assert res.success and kb.read(0) == 20 and kb.read(1) == 10

print("\n=== 4. one op batch, three backends, identical verdicts ===")
initial, ops = increment_batch(n_words=24, k=2, n_ops=8, seed=5)
report = run_differential(ops, initial, algorithm=OURS)
print("  " + report.summary().replace("\n", "\n  "))
assert report.agree
print("quickstart OK")

"""Batched serving with PMwCAS page admission (continuous batching demo).

Requests propose overlapping KV-cache page groups; the batched
deterministic MwCAS primitive grants each group atomically (no partial
allocations, deterministic linearization) — the paper's multi-word
reservation as a TPU data-parallel op.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "llama3-8b", "--smoke",
                "--requests", "16", "--steps", "8"]
    main()
